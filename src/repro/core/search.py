"""Reuse-aware hyperparameter search: the sweep engine as a *tuner*.

``run_sweep`` executes a fixed K-arm grid the user chose up front. This
module closes the loop the ROADMAP names: a :class:`SearchDriver` that
*chooses* the arms, submitting them to a live
:class:`~repro.serve.server.SessionServer` dynamically instead of as one
held batch, following "Exploiting Reuse in Pipeline-Aware Hyperparameter
Tuning" (Li et al., 2019). Four ideas compose:

* **Candidate generation** — ``grid`` (cartesian product over knob
  axes), ``random`` (seeded independent draws per axis), and ``mutate``
  (greedy/beam search: each round keeps the best ``beam_width`` arms by
  the reported metric and expands each with ``children`` seeded
  mutations).
* **Reuse-aware frontier ordering** — before each dispatch, every
  pending candidate is priced by the server's ``estimate`` RPC
  (:meth:`~repro.serve.server.SessionServer.estimate_marginal_cost`):
  compiled DAG cost minus signatures already materialized in the store
  or live in the multiplicity map. The driver submits the candidate with
  the least *marginal* compute — arms adjacent in signature space run
  back-to-back, so shared prefixes are computed once and loaded by the
  rest. Under an arm budget (``max_arms`` < |space|) this beats a FIFO
  frontier outright: FIFO spends the budget on whatever order the grid
  was enumerated in; the reuse frontier spends it where the store has
  already paid.
* **Successive-halving early stopping** — with a
  :class:`HalvingConfig`, arms run at increasing resource levels
  (epochs, iterations, data fraction); each rung promotes the top
  ``1/eta`` fraction by metric and the losers' read pins, ledger
  reservations, and queued work are released immediately through the
  server's cooperative cancellation path (PR 6). ``eager=True`` is the
  ASHA variant: the first finishers promote and the stragglers are
  cancelled mid-run.
* **Lease-following dispatch** — the estimate's ``follow_s`` prices the
  part of a candidate's frontier a *running* leader is already
  producing (``n_live_leases`` counts signatures under an exclusive
  compute lease right now). Ties in marginal cost break toward the
  largest ``follow_s``: the follower is submitted while the leader is
  live, its signatures raise the shared multiplicity to ≥ 2, the
  leader's executor force-persists them (`_LiveShareView`), and the
  follower loads instead of recomputing — following beats queueing.

The driver is a *client*: it speaks the JSON protocol through whatever
:func:`repro.serve.connect` returns, so the same tuning script drives an
in-process server, a unix socket, or TCP unchanged. Candidates must
therefore be registry workflows (``registry={name: factory}`` on the
server) with JSON-able params.

Quickstart::

    from repro.core.search import SearchConfig, tune

    report = tune(workdir, registry={"census": build},
                  workflow="census",
                  axes={"reg": [0.01, 0.1, 1.0], "threshold": [0.5, 0.7]},
                  config=SearchConfig(max_arms=4, metric="check.value"))
    print(report.best().params, report.total_node_computes())
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class HalvingConfig:
    """Successive-halving rungs over one resource knob.

    ``resource`` is the workflow param to scale (e.g. ``train_iters``);
    ``levels`` are its per-rung values, low fidelity first. Each rung
    promotes the top ``ceil(n / eta)`` arms by metric to the next level;
    the rest are cancelled/never promoted (their pins, reservations, and
    queued work are released immediately). ``eager=True`` promotes the
    first finishers instead of waiting for the whole rung (ASHA-style)
    and cancels the stragglers mid-run.
    """

    resource: str = ""
    levels: Sequence[Any] = ()
    eta: float = 2.0
    eager: bool = False


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Knobs of the :class:`SearchDriver`.

    ``strategy``
        ``"grid"`` | ``"random"`` | ``"mutate"`` (see module docstring).
    ``max_arms``
        Arm budget for the first rung (grid/random) or across all rounds
        (mutate). ``None`` = the whole candidate space. The *frontier
        ordering decides which* candidates spend the budget.
    ``frontier``
        ``"reuse"`` (marginal-compute order via the estimate RPC, the
        point of this module) or ``"fifo"`` (enumeration order — the
        baseline the bench compares against).
    ``max_inflight``
        Concurrent submissions the driver keeps live (≈ the server's
        session slots).
    ``seed``
        Seeds the random/mutate RNG and is recorded in the report, so a
        tuning run replays bit-identically.
    ``metric`` / ``maximize``
        Dotted path into a job summary's ``outputs`` (e.g.
        ``"checkResults.value"``) used to rank arms. Required for
        halving and mutate; optional otherwise.
    ``halving``
        A :class:`HalvingConfig` to early-stop losing arms
        (grid/random strategies only).
    ``beam_width`` / ``children`` / ``rounds``
        Mutation search: survivors per round, mutations per survivor,
        and maximum rounds.
    ``poll_interval``
        Driver-side completion poll period (the protocol is pull-based).
    ``priority_rungs``
        Submit rung r at scheduler priority r, so promoted survivors
        outrank fresh low-rung arms on a busy server.
    ``detail``
        Fetch detailed summaries (per-arm computed-signature lists) so
        the report can do fleet duplicate-compute accounting.
    ``on_rung``
        Optional callback ``fn(rung_summary: dict)`` invoked after each
        rung/round settles — the test hook for ledger==disk invariants.
    """

    strategy: str = "grid"
    max_arms: int | None = None
    frontier: str = "reuse"
    max_inflight: int = 2
    seed: int = 0
    metric: str = ""
    maximize: bool = True
    halving: HalvingConfig | None = None
    beam_width: int = 2
    children: int = 2
    rounds: int = 3
    poll_interval: float = 0.02
    priority_rungs: bool = True
    detail: bool = True
    on_rung: Callable[[dict], None] | None = None


@dataclasses.dataclass
class ArmResult:
    """One submitted (or skipped) arm of the search."""

    name: str
    params: dict               # as submitted (includes the resource knob)
    base_params: dict          # without the halving resource knob
    rung: int
    order: int                 # global dispatch sequence of this driver
    job_id: str | None = None
    # queued→running→(done|error|cancelled) server-side; "skipped" means
    # the arm budget or an eager-promotion cut dropped it unsubmitted.
    status: str = "skipped"
    metric: float | None = None
    summary: dict = dataclasses.field(default_factory=dict)
    estimate: dict | None = None   # the frontier estimate at dispatch
    error: str | None = None


@dataclasses.dataclass
class SearchReport:
    """Outcome of one :meth:`SearchDriver.run`."""

    arms: list[ArmResult]
    rungs: list[dict]
    wall_seconds: float
    seed: int
    strategy: str
    frontier: str
    maximize: bool = True

    def best(self) -> ArmResult | None:
        """The finished arm with the best metric (None when no arm
        reported one)."""
        scored = [a for a in self.arms
                  if a.status == "done" and a.metric is not None]
        if not scored:
            return None
        pick = max if self.maximize else min
        return pick(scored, key=lambda a: a.metric)

    def total_node_computes(self) -> int:
        """Nodes actually computed across all arms (planned COMPUTE and
        not turned into a load by the in-flight dedupe) — the
        reuse-efficiency headline the bench compares."""
        total = 0
        for a in self.arms:
            ex = a.summary.get("execution")
            if ex:
                total += int(ex["n_computed"]) - int(ex["n_deduped"])
        return total

    def fleet_computes(self) -> dict[str, int]:
        """How many arms computed each signature (requires
        ``SearchConfig.detail``, the default)."""
        counts: dict[str, int] = {}
        for a in self.arms:
            ex = a.summary.get("execution") or {}
            for sig in ex.get("computed_sigs", ()):
                counts[sig] = counts.get(sig, 0) + 1
        return counts

    def wasted_recomputes(self) -> int:
        """Signatures *blindly* computed more than once — coordination
        failures, excluding the planner's deliberate
        recompute-cheaper-than-load choices (same contract as
        ``SweepReport.wasted_recomputes``; requires
        ``SearchConfig.detail``)."""
        blind: dict[str, int] = {}
        for a in self.arms:
            ex = a.summary.get("execution") or {}
            for sig in ex.get("blind_computed_sigs", ()):
                blind[sig] = blind.get(sig, 0) + 1
        return sum(1 for c in blind.values() if c > 1)

    def n_cancelled(self) -> int:
        """Arms stopped by early stopping (or a server shutdown)."""
        return sum(1 for a in self.arms if a.status == "cancelled")


class _Candidate:
    """A not-yet-submitted arm: base params + enumeration index."""

    __slots__ = ("params", "idx", "_last_est")

    def __init__(self, params: dict, idx: int):
        self.params = params
        self.idx = idx
        self._last_est: dict | None = None


class SearchDriver:
    """Submit arms to a live session server, reuse-aware (module doc).

    ``target`` is anything :func:`repro.serve.connect` accepts — a
    :class:`~repro.serve.server.SessionServer`, a client, a unix-socket
    path, ``"host:port"``, or a ``(host, port)`` tuple. ``workflow`` is
    the server-side registry name; candidates are the JSON param dicts
    its factory accepts.

    Candidate sources (exactly one is required):

    * ``axes`` — ``{param: [values...]}``; the grid strategy enumerates
      the cartesian product (first axis outermost), the random strategy
      draws each param independently per arm.
    * ``space`` — an explicit candidate list of param dicts, in
      enumeration order (what the FIFO frontier would follow).
    * ``base`` + ``mutate`` — the mutation strategy's starting point:
      ``mutate(params, rng) -> params`` proposes a seeded variation.
    """

    def __init__(self, target: Any, workflow: str, *,
                 axes: Mapping[str, Sequence[Any]] | None = None,
                 space: Sequence[Mapping[str, Any]] | None = None,
                 base: Mapping[str, Any] | None = None,
                 mutate: Callable[[dict, Any], dict] | None = None,
                 config: SearchConfig | None = None):
        from ..serve.client import connect   # local: serve imports core
        self.client = connect(target)
        self.workflow = str(workflow)
        self.axes = {k: list(v) for k, v in (axes or {}).items()}
        self.space = [dict(p) for p in (space or [])]
        self.base = dict(base or {})
        self.mutate = mutate
        cfg = config if config is not None else SearchConfig()
        if cfg.strategy not in ("grid", "random", "mutate"):
            raise ValueError(f"unknown strategy {cfg.strategy!r}")
        if cfg.frontier not in ("reuse", "fifo"):
            raise ValueError(f"unknown frontier {cfg.frontier!r}")
        if cfg.strategy == "grid" and not (self.axes or self.space):
            raise ValueError("grid search needs axes= or space=")
        if cfg.strategy == "random":
            if not self.axes:
                raise ValueError("random search needs axes=")
            if cfg.max_arms is None:
                raise ValueError("random search needs max_arms "
                                 "(the number of draws)")
        if cfg.strategy == "mutate":
            if self.mutate is None:
                raise ValueError("mutation search needs mutate=")
            if not cfg.metric:
                raise ValueError("mutation search ranks by metric; set "
                                 "SearchConfig.metric")
            if cfg.halving is not None:
                raise ValueError("halving applies to grid/random "
                                 "strategies (mutation has its own "
                                 "round-based early stopping)")
        if cfg.halving is not None:
            if not cfg.halving.resource or not cfg.halving.levels:
                raise ValueError("HalvingConfig needs resource and a "
                                 "non-empty levels sequence")
            if not cfg.metric:
                raise ValueError("halving ranks by metric; set "
                                 "SearchConfig.metric")
            if cfg.halving.eta <= 1.0:
                raise ValueError("halving eta must be > 1")
        self.config = cfg
        self._order = 0
        self._submitted = 0

    # -- public ------------------------------------------------------------
    def run(self) -> SearchReport:
        """Run the configured search to completion; returns the report."""
        t0 = time.perf_counter()
        if self.config.strategy == "mutate":
            arms, rungs = self._run_mutation()
        else:
            arms, rungs = self._run_rungs()
        arms.sort(key=lambda a: a.order)
        return SearchReport(
            arms=arms, rungs=rungs,
            wall_seconds=time.perf_counter() - t0,
            seed=self.config.seed, strategy=self.config.strategy,
            frontier=self.config.frontier,
            maximize=self.config.maximize)

    # -- candidate generation ----------------------------------------------
    def _initial_candidates(self) -> list[_Candidate]:
        cfg = self.config
        if cfg.strategy == "random":
            rng = np.random.default_rng(cfg.seed)
            out, seen = [], set()
            # Bounded rejection sampling: duplicates are redrawn, but a
            # small space must not loop forever.
            for _ in range(cfg.max_arms * 16):
                if len(out) >= cfg.max_arms:
                    break
                p = {k: v[int(rng.integers(len(v)))]
                     for k, v in self.axes.items()}
                key = self._key(p)
                if key in seen:
                    continue
                seen.add(key)
                out.append(_Candidate(p, len(out)))
            return out
        if self.space:
            return [_Candidate(dict(p), i)
                    for i, p in enumerate(self.space)]
        import itertools
        keys = list(self.axes)
        return [_Candidate(dict(zip(keys, combo)), i)
                for i, combo in enumerate(
                    itertools.product(*(self.axes[k] for k in keys)))]

    @staticmethod
    def _key(params: Mapping[str, Any]) -> tuple:
        return tuple(sorted((k, repr(v)) for k, v in params.items()))

    # -- rung/round engines --------------------------------------------------
    def _run_rungs(self) -> tuple[list[ArmResult], list[dict]]:
        cfg = self.config
        halving = cfg.halving
        levels: Sequence[Any] = halving.levels if halving else (None,)
        cands = self._initial_candidates()
        all_arms: list[ArmResult] = []
        rungs: list[dict] = []
        for rung, level in enumerate(levels):
            last = rung == len(levels) - 1
            n_promote = None if last else max(
                1, math.ceil(len(cands) / halving.eta))
            eager_quota = n_promote if (halving and halving.eager
                                        and not last) else None
            arms, eager_winners = self._dispatch_batch(
                cands, rung=rung, level=level,
                budget=cfg.max_arms if rung == 0 else None,
                eager_quota=eager_quota)
            all_arms.extend(arms)
            if eager_quota is not None:
                promoted = eager_winners
            elif n_promote is not None:
                ranked = sorted(
                    (a for a in arms
                     if a.status == "done" and a.metric is not None),
                    key=lambda a: a.metric, reverse=cfg.maximize)
                promoted = ranked[:n_promote]
            else:
                promoted = []
            summary = self._rung_summary(rung, level, arms, promoted)
            rungs.append(summary)
            if cfg.on_rung is not None:
                cfg.on_rung(summary)
            if last or not promoted:
                break
            cands = [_Candidate(dict(a.base_params), i)
                     for i, a in enumerate(promoted)]
        return all_arms, rungs

    def _run_mutation(self) -> tuple[list[ArmResult], list[dict]]:
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)
        population = [dict(p) for p in (self.space or [dict(self.base)])]
        seen = {self._key(p) for p in population}
        all_arms: list[ArmResult] = []
        rounds: list[dict] = []
        for rnd in range(cfg.rounds):
            budget = None if cfg.max_arms is None \
                else cfg.max_arms - self._submitted
            if budget is not None and budget <= 0:
                break
            cands = [_Candidate(p, i) for i, p in enumerate(population)]
            arms, _ = self._dispatch_batch(cands, rung=rnd, level=None,
                                           budget=budget)
            all_arms.extend(arms)
            ranked = sorted(
                (a for a in arms
                 if a.status == "done" and a.metric is not None),
                key=lambda a: a.metric, reverse=cfg.maximize)
            beam = ranked[:cfg.beam_width]
            summary = self._rung_summary(rnd, None, arms, beam)
            rounds.append(summary)
            if cfg.on_rung is not None:
                cfg.on_rung(summary)
            population = []
            for parent in beam:
                for _ in range(cfg.children):
                    child = self.mutate(dict(parent.base_params), rng)
                    key = self._key(child)
                    if key not in seen:
                        seen.add(key)
                        population.append(dict(child))
            if not population:
                break
        return all_arms, rounds

    @staticmethod
    def _rung_summary(rung: int, level: Any, arms: list[ArmResult],
                      promoted: list[ArmResult]) -> dict:
        return {
            "rung": rung, "level": level, "n_arms": len(arms),
            "n_done": sum(1 for a in arms if a.status == "done"),
            "n_error": sum(1 for a in arms if a.status == "error"),
            "n_cancelled": sum(1 for a in arms
                               if a.status == "cancelled"),
            "n_skipped": sum(1 for a in arms if a.status == "skipped"),
            "promoted": [a.name for a in promoted],
        }

    # -- the dispatch loop ---------------------------------------------------
    def _full_params(self, cand: _Candidate, level: Any) -> dict:
        params = dict(cand.params)
        if level is not None:
            params[self.config.halving.resource] = level
        return params

    def _pick(self, pending: list[_Candidate], level: Any) -> _Candidate:
        """Choose the next candidate off the frontier.

        ``"fifo"``: enumeration order. ``"reuse"``: re-estimate every
        pending candidate against the server's *current* store and
        in-flight state and take the least marginal compute; ties break
        toward the largest ``follow_s`` (prefer drafting behind a live
        leader — lease-following dispatch), then enumeration order.
        Estimates are refreshed at every pick because each completed arm
        changes what the store holds.
        """
        if self.config.frontier == "fifo" or len(pending) == 1:
            return pending[0]
        best, best_key = None, None
        for cand in pending:
            est = self.client.estimate(self.workflow,
                                       self._full_params(cand, level))
            key = (est["marginal_s"], -est["follow_s"], cand.idx)
            if best_key is None or key < best_key:
                best, best_key, best_est = cand, key, est
        best._last_est = best_est
        return best

    def _dispatch_batch(self, cands: list[_Candidate], *, rung: int,
                        level: Any, budget: int | None = None,
                        eager_quota: int | None = None
                        ) -> tuple[list[ArmResult], list[ArmResult]]:
        """Run one rung/round: windowed dynamic dispatch + completion poll.

        Keeps up to ``max_inflight`` submissions live, choosing each next
        submission with :meth:`_pick`. ``budget`` bounds submissions
        (leftover candidates become ``skipped`` arms — the frontier
        ordering thereby decides *which* arms spend the budget).
        ``eager_quota`` turns on ASHA promotion: the first that many
        finishers win and every other live submission of the rung is
        cancelled immediately (pins/reservations release server-side).
        Returns ``(all arms of this rung, eager winners)``.
        """
        cfg = self.config
        pending = list(cands)
        inflight: dict[str, ArmResult] = {}
        finished: list[ArmResult] = []
        winners: list[ArmResult] = []
        n_submitted = 0

        def _skip_rest() -> None:
            for cand in pending:
                finished.append(ArmResult(
                    name=self._arm_name(cand, rung), rung=rung,
                    params=self._full_params(cand, level),
                    base_params=dict(cand.params),
                    order=self._next_order()))
            pending.clear()

        while pending or inflight:
            while (pending and len(inflight) < cfg.max_inflight
                   and (budget is None or n_submitted < budget)):
                cand = self._pick(pending, level)
                pending.remove(cand)
                arm = ArmResult(
                    name=self._arm_name(cand, rung), rung=rung,
                    params=self._full_params(cand, level),
                    base_params=dict(cand.params),
                    order=self._next_order(),
                    estimate=getattr(cand, "_last_est", None))
                try:
                    arm.job_id = self.client.submit(
                        self.workflow, arm.params, name=arm.name,
                        priority=rung if cfg.priority_rungs else 0)
                except Exception as e:
                    arm.status = "error"
                    arm.error = f"{type(e).__name__}: {e}"
                    finished.append(arm)
                    continue
                arm.status = "queued"
                self._submitted += 1
                n_submitted += 1
                inflight[arm.job_id] = arm
            if pending and (budget is not None and n_submitted >= budget):
                _skip_rest()
            progressed = False
            for job_id, arm in list(inflight.items()):
                s = self.client.job(job_id, detail=cfg.detail)
                if s["status"] not in ("done", "error", "cancelled"):
                    arm.status = s["status"]
                    continue
                progressed = True
                inflight.pop(job_id)
                self._finalize(arm, s)
                finished.append(arm)
                if (eager_quota is not None and arm.status == "done"
                        and arm.metric is not None
                        and len(winners) < eager_quota):
                    winners.append(arm)
                    if len(winners) >= eager_quota:
                        # Quota filled: the rest of the rung are losers.
                        # Cancel the live ones (the server releases
                        # their pins/reservations on the way out) and
                        # skip the unsubmitted ones.
                        for other_id in list(inflight):
                            self.client.cancel(other_id)
                        for other_id, other in list(inflight.items()):
                            self._finalize(
                                other,
                                self.client.wait(other_id,
                                                 detail=cfg.detail))
                            finished.append(other)
                        inflight.clear()
                        _skip_rest()
                        break   # the items() snapshot is stale now
            if (pending or inflight) and not progressed:
                time.sleep(cfg.poll_interval)
        return finished, winners

    def _finalize(self, arm: ArmResult, summary: dict) -> None:
        arm.status = summary["status"]
        arm.summary = summary
        arm.error = summary.get("error")
        if arm.status == "done" and self.config.metric:
            arm.metric = self._metric(summary)

    def _metric(self, summary: Mapping[str, Any]) -> float | None:
        """Extract the configured dotted metric path from ``outputs``."""
        cur: Any = summary.get("outputs", {})
        for part in self.config.metric.split("."):
            if isinstance(cur, Mapping) and part in cur:
                cur = cur[part]
            else:
                return None
        try:
            return float(cur)
        except (TypeError, ValueError):
            return None

    def _next_order(self) -> int:
        self._order += 1
        return self._order

    def _arm_name(self, cand: _Candidate, rung: int) -> str:
        label = ",".join(f"{k}={cand.params[k]}"
                         for k in sorted(cand.params))
        return f"{self.workflow}[{label[:80]}]@r{rung}"


def tune(workdir: str, registry: Mapping[str, Callable[..., Any]],
         workflow: str, *,
         axes: Mapping[str, Sequence[Any]] | None = None,
         space: Sequence[Mapping[str, Any]] | None = None,
         base: Mapping[str, Any] | None = None,
         mutate: Callable[[dict, Any], dict] | None = None,
         config: SearchConfig | None = None,
         engine: Any = None, storage: Any = None,
         resilience: Any = None) -> SearchReport:
    """One-call tuning: spin a server over ``workdir``, search, shut down.

    Constructs an in-process
    :class:`~repro.serve.server.SessionServer` with ``registry`` and the
    given config dataclasses (``engine.n_sessions`` defaults to the
    search's ``max_inflight`` so the dispatch window matches the slot
    count), runs a :class:`SearchDriver` against it, and always shuts
    the server down. Everything else matches :class:`SearchDriver`.
    """
    from ..serve.server import SessionServer   # local: serve imports core
    from .config import EngineConfig
    cfg = config if config is not None else SearchConfig()
    if engine is None:
        engine = EngineConfig(n_sessions=cfg.max_inflight)
    elif engine.n_sessions is None:
        engine = dataclasses.replace(engine, n_sessions=cfg.max_inflight)
    server = SessionServer(workdir, registry=dict(registry),
                           engine=engine, storage=storage,
                           resilience=resilience)
    try:
        driver = SearchDriver(server, workflow, axes=axes, space=space,
                              base=base, mutate=mutate, config=cfg)
        return driver.run()
    finally:
        server.shutdown()
