"""Workflow DAG pruning (paper §5.4).

* ``slice_from_outputs`` — program slicing: keep only ancestors of outputs
  (plus explicit ``uses`` dependencies, which the DSL already encodes as
  edges). The raceExt example in the paper's Fig. 3 is pruned this way.
* ``zero_weight_extractors`` — data-driven pruning: given a trained linear
  model's weights and per-feature provenance (which extractor produced each
  feature column), report extractors whose every feature has |w| below
  tolerance; these can be dropped in the next iteration without changing
  predictions.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from .dag import DAG


def slice_from_outputs(dag: DAG) -> set[str]:
    keep: set[str] = set()
    stack = list(dag.outputs())
    while stack:
        cur = stack.pop()
        if cur in keep:
            continue
        keep.add(cur)
        stack.extend(dag.nodes[cur].parents)
    return keep


def zero_weight_extractors(weights: np.ndarray,
                           provenance: Mapping[str, Sequence[int]],
                           tol: float = 1e-8) -> set[str]:
    """Extractors whose features all have |weight| < tol (prunable)."""
    w = np.asarray(weights).reshape(-1)
    prunable = set()
    for extractor, cols in provenance.items():
        cols = list(cols)
        if cols and np.all(np.abs(w[cols]) < tol):
            prunable.add(extractor)
    return prunable
