"""Workflow DAG pruning (paper §5.4).

* ``slice_from_outputs`` — program slicing: keep only ancestors of outputs
  (plus explicit ``uses`` dependencies, which the DSL already encodes as
  edges). The raceExt example in the paper's Fig. 3 is pruned this way.
* ``zero_weight_extractors`` — data-driven pruning: given a trained linear
  model's weights and per-feature provenance (which extractor produced each
  feature column), report extractors whose every feature has |w| below
  tolerance; these can be dropped in the next iteration without changing
  predictions.
* ``stale_variants`` — the §6.6 purge's selection rule: which store
  signatures are *stale* materializations of this iteration's original
  nodes (same node name, different signature). Extracted from the session
  so the suppression rules — never the node's own current signature, only
  names actually original this iteration — are unit-testable without a
  store.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from .dag import DAG


def slice_from_outputs(dag: DAG) -> set[str]:
    keep: set[str] = set()
    stack = list(dag.outputs())
    while stack:
        cur = stack.pop()
        if cur in keep:
            continue
        keep.add(cur)
        stack.extend(dag.nodes[cur].parents)
    return keep


def stale_variants(by_name: Mapping[str, Sequence[str]],
                   original: set[str],
                   sigs: Mapping[str, str]) -> list[str]:
    """Store signatures the §6.6 purge should delete, in deterministic
    order: every stored signature under an *original* node's name except
    the node's own current signature. Names that are not original this
    iteration are untouched — their stored variants may belong to sibling
    sessions (sweep mode) or to this session's own still-equivalent past.
    The caller handles chunk protection (``Store.delete(keep_chunks=…)``):
    a stale chunked manifest's *prefix chunks* are typically shared with
    the delta manifest about to be computed."""
    out: list[str] = []
    for n in sorted(original):
        for old_sig in by_name.get(n, []):
            if old_sig != sigs[n]:
                out.append(old_sig)
    return out


def zero_weight_extractors(weights: np.ndarray,
                           provenance: Mapping[str, Sequence[int]],
                           tol: float = 1e-8) -> set[str]:
    """Extractors whose features all have |weight| < tol (prunable)."""
    w = np.asarray(weights).reshape(-1)
    prunable = set()
    for extractor, cols in provenance.items():
        cols = list(cols)
        if cols and np.all(np.abs(w[cols]) < tol):
            prunable.add(extractor)
    return prunable
