"""Workflow DAG — the paper's central abstraction (§4.1, Def. 1).

Nodes correspond to *operator outputs*; edges to input→output relationships.
Each node carries the callable that produces its output from its parents'
outputs, plus the metadata the optimizer needs (version string for change
tracking, determinism flag, output flag).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Mapping, Sequence


class State(enum.Enum):
    """Execution state assignment (paper §5.1): compute / load / prune."""

    COMPUTE = "compute"
    LOAD = "load"
    PRUNE = "prune"


class Kind(enum.Enum):
    """Operator kinds mirroring the HML interfaces (paper §3.2.2)."""

    SOURCE = "source"          # data source (root; l_i == c_i in the paper)
    SCANNER = "scanner"        # parsing / flatMap
    EXTRACTOR = "extractor"    # feature extraction / transformation
    SYNTHESIZER = "synthesizer"  # join / example assembly
    LEARNER = "learner"        # learning + inference
    REDUCER = "reducer"        # PPR reduce
    SEGMENT = "segment"        # a training segment (N optimizer steps) — the
                               # unit of fault-tolerant reuse in Helix-JAX


@dataclasses.dataclass(frozen=True)
class Node:
    """A single operator output in the Workflow DAG."""

    name: str
    fn: Callable[..., Any]
    parents: tuple[str, ...] = ()
    kind: Kind = Kind.EXTRACTOR
    # ``version`` participates in the signature: editing an operator between
    # iterations means giving it a new version (the DSL hashes source/config).
    version: str = "0"
    # Nondeterministic operators (e.g. unseeded random featurization, as in
    # the paper's MNIST workflow) can never be reused across iterations.
    deterministic: bool = True
    # Mandatory output (HML ``is_output``): must not be pruned and is always
    # materialized by the executor.
    is_output: bool = False
    # Optional a-priori compute-cost estimate in seconds (e.g. derived from a
    # dry-run roofline) used when no measured statistics exist yet.
    cost_hint: float | None = None


class DAG:
    """An immutable-ish DAG of :class:`Node` keyed by name."""

    def __init__(self, nodes: Sequence[Node]):
        self.nodes: dict[str, Node] = {}
        for n in nodes:
            if n.name in self.nodes:
                raise ValueError(f"duplicate node name: {n.name}")
            self.nodes[n.name] = n
        for n in nodes:
            for p in n.parents:
                if p not in self.nodes:
                    raise ValueError(f"{n.name}: unknown parent {p!r}")
        self._children: dict[str, list[str]] = {k: [] for k in self.nodes}
        for n in nodes:
            for p in n.parents:
                self._children[p].append(n.name)
        self._order = self._toposort()

    # -- structure ---------------------------------------------------------
    def children(self, name: str) -> list[str]:
        return self._children[name]

    def parents(self, name: str) -> tuple[str, ...]:
        return self.nodes[name].parents

    def topological(self) -> list[str]:
        return list(self._order)

    def ancestors(self, name: str) -> set[str]:
        out: set[str] = set()
        stack = list(self.nodes[name].parents)
        while stack:
            cur = stack.pop()
            if cur not in out:
                out.add(cur)
                stack.extend(self.nodes[cur].parents)
        return out

    def outputs(self) -> list[str]:
        return [n.name for n in self.nodes.values() if n.is_output]

    def subgraph(self, keep: set[str]) -> "DAG":
        return DAG([self.nodes[k] for k in self._order if k in keep])

    def _toposort(self) -> list[str]:
        indeg = {k: len(n.parents) for k, n in self.nodes.items()}
        # Deterministic order: seed with insertion order.
        ready = [k for k in self.nodes if indeg[k] == 0]
        order: list[str] = []
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for ch in self._children[cur]:
                indeg[ch] -= 1
                if indeg[ch] == 0:
                    ready.append(ch)
        if len(order) != len(self.nodes):
            raise ValueError("cycle detected in workflow DAG")
        return order

    def __len__(self) -> int:
        return len(self.nodes)

    def __contains__(self, name: str) -> bool:
        return name in self.nodes


def validate_states(dag: DAG, states: Mapping[str, State]) -> None:
    """Check Constraint 2 (computed node's parents not pruned) and that
    mandatory outputs are not pruned. Raises ``ValueError`` on violation."""
    for name, node in dag.nodes.items():
        s = states[name]
        if s is State.COMPUTE:
            for p in node.parents:
                if states[p] is State.PRUNE:
                    raise ValueError(
                        f"Constraint 2 violated: {name} computed but parent "
                        f"{p} pruned")
        if node.is_output and s is State.PRUNE:
            raise ValueError(f"output node {name} pruned")
