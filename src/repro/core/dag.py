"""Workflow DAG — the paper's central abstraction (§4.1, Def. 1).

Nodes correspond to *operator outputs*; edges to input→output relationships.
Each node carries the callable that produces its output from its parents'
outputs, plus the metadata the optimizer needs (version string for change
tracking, determinism flag, output flag).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Mapping, Sequence


class State(enum.Enum):
    """Execution state assignment (paper §5.1): compute / load / prune."""

    COMPUTE = "compute"
    LOAD = "load"
    PRUNE = "prune"


class Kind(enum.Enum):
    """Operator kinds mirroring the HML interfaces (paper §3.2.2)."""

    SOURCE = "source"          # data source (root; l_i == c_i in the paper)
    SCANNER = "scanner"        # parsing / flatMap
    EXTRACTOR = "extractor"    # feature extraction / transformation
    SYNTHESIZER = "synthesizer"  # join / example assembly
    LEARNER = "learner"        # learning + inference
    REDUCER = "reducer"        # PPR reduce
    SEGMENT = "segment"        # a training segment (N optimizer steps) — the
                               # unit of fault-tolerant reuse in Helix-JAX


@dataclasses.dataclass(frozen=True)
class Node:
    """A single operator output in the Workflow DAG."""

    name: str
    fn: Callable[..., Any]
    parents: tuple[str, ...] = ()
    kind: Kind = Kind.EXTRACTOR
    # ``version`` participates in the signature: editing an operator between
    # iterations means giving it a new version (the DSL hashes source/config).
    version: str = "0"
    # Nondeterministic operators (e.g. unseeded random featurization, as in
    # the paper's MNIST workflow) can never be reused across iterations.
    deterministic: bool = True
    # Mandatory output (HML ``is_output``): must not be pruned and is always
    # materialized by the executor.
    is_output: bool = False
    # Optional a-priori compute-cost estimate in seconds (e.g. derived from a
    # dry-run roofline) used when no measured statistics exist yet.
    cost_hint: float | None = None
    # Operator capability for incremental recomputation on data deltas
    # (chunks.py): "map" (row-local, applies per chunk), "union"
    # (row-concat of parents), "assoc_reduce" (chunk → partial, partials
    # combine associatively), or None (opaque: whole-subtree recompute on
    # any input change).
    incremental: str | None = None
    # Chunked sources only: one stable identity per data chunk (hash of
    # the chunk's descriptor). Appending a batch appends an id; the
    # prefix ids — and therefore the prefix chunk signatures — survive,
    # which is what makes the delta the only new work.
    chunk_ids: tuple[str, ...] | None = None


class DAG:
    """An immutable-ish DAG of :class:`Node` keyed by name."""

    def __init__(self, nodes: Sequence[Node]):
        self.nodes: dict[str, Node] = {}
        for n in nodes:
            if n.name in self.nodes:
                raise ValueError(f"duplicate node name: {n.name}")
            self.nodes[n.name] = n
        for n in nodes:
            for p in n.parents:
                if p not in self.nodes:
                    raise ValueError(f"{n.name}: unknown parent {p!r}")
        self._children: dict[str, list[str]] = {k: [] for k in self.nodes}
        for n in nodes:
            for p in n.parents:
                self._children[p].append(n.name)
        self._order = self._toposort()

    # -- structure ---------------------------------------------------------
    def children(self, name: str) -> list[str]:
        return self._children[name]

    def parents(self, name: str) -> tuple[str, ...]:
        return self.nodes[name].parents

    def topological(self) -> list[str]:
        return list(self._order)

    def ancestors(self, name: str) -> set[str]:
        out: set[str] = set()
        stack = list(self.nodes[name].parents)
        while stack:
            cur = stack.pop()
            if cur not in out:
                out.add(cur)
                stack.extend(self.nodes[cur].parents)
        return out

    def outputs(self) -> list[str]:
        return [n.name for n in self.nodes.values() if n.is_output]

    def subgraph(self, keep: set[str]) -> "DAG":
        return DAG([self.nodes[k] for k in self._order if k in keep])

    def _toposort(self) -> list[str]:
        indeg = {k: len(n.parents) for k, n in self.nodes.items()}
        # Deterministic order: seed with insertion order.
        ready = [k for k in self.nodes if indeg[k] == 0]
        order: list[str] = []
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for ch in self._children[cur]:
                indeg[ch] -= 1
                if indeg[ch] == 0:
                    ready.append(ch)
        if len(order) != len(self.nodes):
            raise ValueError("cycle detected in workflow DAG")
        return order

    # -- scheduler support (executor's ready-set engine) -------------------
    def exec_indegree(self, states: Mapping[str, State]) -> dict[str, int]:
        """Unfinished-dependency count per runnable node under a plan.

        COMPUTE nodes wait on every non-pruned parent (Constraint 2 says
        there are no pruned ones; if a broken plan violates that, the node
        runs anyway and fails with the sequential engine's KeyError instead
        of deadlocking the pool). LOAD nodes are pure store I/O with no
        dependencies, so they are ready — and prefetchable — the moment
        planning finishes. PRUNE nodes never run and are omitted.
        """
        indeg: dict[str, int] = {}
        for name, node in self.nodes.items():
            s = states[name]
            if s is State.PRUNE:
                continue
            indeg[name] = (sum(1 for p in node.parents
                               if states[p] is not State.PRUNE)
                           if s is State.COMPUTE else 0)
        return indeg

    def oos_order(self, states: Mapping[str, State]) -> list[str]:
        """The deterministic out-of-scope sequence of the sequential engine.

        Replays the topological sweep symbolically: a node goes out of scope
        (Def. 5 / Constraint 3) when its last COMPUTE-state child executes,
        or immediately after its own execution if it has none. The parallel
        scheduler processes materialization decisions strictly in this order
        so OMP decisions and budget accounting are identical for any worker
        count.
        """
        remaining = {
            name: sum(1 for ch in self._children[name]
                      if states[ch] is State.COMPUTE)
            for name in self.nodes
        }
        order: list[str] = []
        for name in self._order:
            s = states[name]
            if s is State.PRUNE:
                continue
            if s is State.COMPUTE:
                for p in self.nodes[name].parents:
                    remaining[p] -= 1
                    if remaining[p] == 0 and states[p] is not State.PRUNE:
                        order.append(p)
            if remaining[name] == 0:
                order.append(name)
        return order

    def __len__(self) -> int:
        return len(self.nodes)

    def __contains__(self, name: str) -> bool:
        return name in self.nodes


def validate_states(dag: DAG, states: Mapping[str, State]) -> None:
    """Check Constraint 2 (computed node's parents not pruned) and that
    mandatory outputs are not pruned. Raises ``ValueError`` on violation."""
    for name, node in dag.nodes.items():
        s = states[name]
        if s is State.COMPUTE:
            for p in node.parents:
                if states[p] is State.PRUNE:
                    raise ValueError(
                        f"Constraint 2 violated: {name} computed but parent "
                        f"{p} pruned")
        if node.is_output and s is State.PRUNE:
            raise ValueError(f"output node {name} pruned")
