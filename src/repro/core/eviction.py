"""Benefit-weighted fleet eviction (the paper's storage-budget story,
closed at fleet scale).

OPT-MAT-PLAN's budget S makes materialization a Knapsack (Appendix C):
Algorithm 2 decides *what to write*, but once S is exhausted the old
behavior was refuse-on-exhausted — a high-benefit intermediate (large
C(n)/l_i, many live readers) was rejected while a stale low-benefit entry
squatted in the store forever. :class:`Evictor` converts the store into a
real cache: when a reservation does not fit, it deletes the lowest-benefit
*unleased* entries until it does (evict-to-admit).

Benefit density per entry (the Knapsack value-per-byte, following Li et
al. 2019's observation that *observed* pipeline reuse dominates tuning
workloads)::

    density(e) = (C(n_e) / l_e) · (1 + reuse(e))

* ``C(n_e)`` — cost-to-recompute (cumulative runtime, Def. 6), persisted
  by the executor at save time (``meta.json``/index key ``compute_s``).
  Entries from before this metadata existed score 0 and go first — they
  are exactly the stale squatters.
* ``l_e`` — the load-cost estimate (``load_s_est`` at save time, else
  bytes / measured store bandwidth). Since l_e scales with bytes,
  ``C/l`` is already a per-byte density: recompute-seconds saved per
  byte of budget held.
* ``reuse(e)`` — observed future-load evidence: the entry's recorded
  load count (``Store._note_load``) or the cost model's fleet-merged
  historical reuse count for its signature, whichever is larger.

Two hard vetoes keep eviction safe under concurrency:

* **Live multiplicity** — signatures the session server's live
  cross-client map says queued/running clients still want are never
  candidates (the server passes ``PrefixScheduler.is_live``).
* **Leases** — deletion goes through :meth:`Store.delete`'s
  lease-respecting path, so entries pinned for a planned LOAD or being
  computed right now are skipped atomically (the lease is *held* for the
  removal, not probed).

Every freed byte is credited to the shared :class:`StorageLedger`
atomically (via the caller's ``credit`` callback —
``Materializer.credit_foreign``), so N concurrent sessions see one
consistent budget. The evictor itself is policy + a loop; it owns no
budget state and can be shared by every session of a server (its stats
then aggregate fleet-wide).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable


def benefit_density(compute_s: float, load_s: float,
                    expected_uses: float) -> float:
    """``(C/l) · (1 + expected future uses)`` — the one formula every
    site shares: the evictor's ranking (``expected_uses`` = observed
    reuse), OMP's admission limit (= effective horizon − 1), and the
    in-flight dedupe's force-persist (= waiting sessions). One body, so
    the evict-vs-admit comparison can never become apples-to-oranges."""
    return (float(compute_s) / max(float(load_s), 1e-9)) \
        * (1.0 + max(float(expected_uses), 0.0))


def ranked_mem(entries: dict[str, dict],
               est_disk_load: Callable[[float], float]) -> list[str]:
    """Rank memory-tier entries cheapest-to-demote first.

    The per-tier analog of :meth:`Evictor.ranked`, sharing
    :func:`benefit_density` so the memory tier's demote-vs-keep and the
    disk tier's evict-vs-admit can never use different value scales.
    Demotion — not deletion — is the action being priced:

    * A **clean** entry (a committed disk copy exists, or the writer
      queue owns one in flight) demotes by dropping the RAM reference;
      losing it costs one disk reload, so ``cost_s = l_disk`` and its
      density reduces to ``1 + loads`` — pure observed-reuse ranking.
    * A **dirty** entry (memory-only, write-back mode) must be spilled
      before it can be dropped, and until the spill lands losing it
      costs a full recompute: ``cost_s = max(C(n), l_disk)``.

    ``entries`` maps sig → ``{nbytes, loads, last_load, created, dirty,
    compute_s}``; ``est_disk_load`` prices the next tier down. Returns
    signatures ascending by density, ties broken least-recently-used
    (then oldest) — identical tie-breaking to the disk evictor.
    """
    scored = []
    for sig, e in entries.items():
        l_disk = max(float(est_disk_load(float(e.get("nbytes", 0) or 1))),
                     1e-9)
        if e.get("dirty"):
            cost_s = max(float(e.get("compute_s", 0.0) or 0.0), l_disk)
        else:
            cost_s = l_disk
        density = benefit_density(cost_s, l_disk,
                                  float(e.get("loads", 0) or 0))
        scored.append((density,
                       e.get("last_load") or e.get("created", 0.0),
                       sig))
    scored.sort()
    return [sig for _, _, sig in scored]


@dataclasses.dataclass
class EvictionStats:
    """Counters for one evictor's lifetime (fleet-wide when shared)."""

    n_calls: int = 0            # evict_to_fit invocations that found a deficit
    n_evicted: int = 0          # entries actually deleted
    bytes_evicted: int = 0      # their recorded on-disk bytes
    n_vetoed_live: int = 0      # candidates protected by live multiplicity
    n_skipped_leased: int = 0   # candidates whose lease (pin/compute) held
    n_unsatisfied: int = 0      # calls that could not free the full deficit

    def snapshot(self) -> dict:
        """JSON-safe copy (server status / benchmark reporting)."""
        return dataclasses.asdict(self)


class Evictor:
    """Evict-to-admit under the shared storage budget.

    ``live_multiplicity`` is the veto callable (``sig -> bool``); the
    session server passes a view over its live cross-client multiplicity
    map. ``cost_model`` supplies historical reuse counts
    (:meth:`CostModel.reuse_counts`); both are optional — a standalone
    session still gets cost-metadata-ranked LRU-tie-broken eviction.
    ``on_evict`` is an audit observer called as ``on_evict(sig, entry,
    freed_bytes)`` after each successful eviction — the multi-tenant
    server records these so the isolation harness can *prove* no live
    or leased entry was ever evicted (observer exceptions are swallowed;
    auditing must not break admission).
    """

    def __init__(self, store, cost_model=None,
                 live_multiplicity: Callable[[str], bool] | None = None,
                 on_evict: Callable[[str, dict, float], None] | None = None):
        self.store = store
        self.cost_model = cost_model
        self.live_multiplicity = live_multiplicity
        self.on_evict = on_evict
        self.stats = EvictionStats()
        # Serializes rankings within this process; cross-process safety
        # comes from Store.delete's lease+lock path and the ledger's
        # transactional credit, not from this lock.
        self._lock = threading.Lock()

    # -- ranking -----------------------------------------------------------
    def _density(self, sig: str, ent: dict,
                 reuse_hist: dict[str, float]) -> float:
        # A chunked manifest is priced (and evicted) as manifest+chunks:
        # deleting it cascades to its unshared chunk entries, so its
        # footprint for ranking is the whole partitioned value.
        nbytes = max(float(ent.get("nbytes", 0) or 0)
                     + float(ent.get("chunk_bytes", 0) or 0), 1.0)
        load_s = ent.get("load_s_est")
        if not load_s or load_s <= 0:
            load_s = self.store.est_load_seconds(nbytes)
        load_s = max(float(load_s), 1e-9)
        cost_s = float(ent.get("compute_s", 0.0) or 0.0)
        reuse = max(float(ent.get("loads", 0) or 0),
                    reuse_hist.get(sig, 0.0))
        if cost_s <= 0:
            # No save-time cost metadata (pre-metadata entry). Fall back
            # to the cost model's measured compute seconds; failing that,
            # an entry with *observed loads* is floored at its own load
            # cost — sessions keep choosing LOAD for it, so recomputing
            # is worth at least one load, and the (1+reuse) protection
            # must not be nullified by a missing key (a hot shared
            # prefix would otherwise rank below cold junk).
            if self.cost_model is not None:
                cost_s = float(self.cost_model.compute_cost(sig,
                                                            default=0.0))
            if cost_s <= 0 and reuse > 0:
                cost_s = load_s
        remote = getattr(self.store, "remote", None)
        if remote is not None and remote.exists(sig):
            # Multi-tier: a remotely-committed entry is recoverable by a
            # refetch, never a recompute — its local copy is worth at
            # most one load no matter how expensive the original compute
            # was. Remote-backed entries therefore yield the local cache
            # first, which is exactly the tiering you want: the local
            # disk holds what only it can cheaply restore.
            cost_s = min(cost_s, load_s)
        return benefit_density(cost_s, load_s, reuse)

    def ranked(self) -> list[tuple[str, dict, float]]:
        """Store entries as ``(sig, entry, density)``, ranked
        cheapest-to-evict first: ascending benefit density, ties broken
        least-recently-used (then oldest)."""
        reuse_hist = (self.cost_model.reuse_counts()
                      if self.cost_model is not None else {})
        # Chunk entries never rank on their own: chunks ride with (and
        # fall with) the manifests that reference them — the manifest is
        # the eviction unit, and its delete cascade frees the chunks.
        scored = [(sig, ent, self._density(sig, ent, reuse_hist))
                  for sig, ent in self.store.entries().items()
                  if not ent.get("is_chunk")]
        scored.sort(key=lambda it: (it[2], it[1].get("last_load")
                                    or it[1].get("created", 0.0)))
        return scored

    # -- the evict-to-admit loop -------------------------------------------
    def evict_to_fit(self, need_bytes: float, budget: float,
                     used: Callable[[], float],
                     credit: Callable[[float], None],
                     limit_density: float | None = None) -> int:
        """Free store bytes until ``used() + need_bytes <= budget``.

        ``used`` reads the current budget occupancy (the shared ledger in
        fleet mode); ``credit`` receives each eviction's freed bytes for
        atomic crediting (``Materializer.credit_foreign``).
        ``limit_density`` is the *incoming* write's own benefit density:
        candidates at or above it are never evicted — admitting a
        barely-qualifying value by deleting strictly more valuable
        entries is a net fleet loss (None = no limit, e.g. mandatory
        outputs, which must persist regardless).

        Returns the bytes actually freed — possibly short of the deficit
        when every remaining entry is leased, live, or too valuable (the
        caller's reservation then simply fails, exactly the old
        refuse-on-exhausted behavior). A reservation that cannot fit
        even into an *empty* store (``need_bytes > budget``) is refused
        up front rather than wiping the cache and failing anyway.
        """
        with self._lock:
            if float(need_bytes) > float(budget):
                self.stats.n_calls += 1
                self.stats.n_unsatisfied += 1
                return 0
            freed_total = 0
            # Two passes: concurrent sessions admit/evict under us, so a
            # still-short first pass re-reads the ledger and the index
            # once before giving up.
            for attempt in range(2):
                deficit = used() + float(need_bytes) - float(budget)
                if deficit <= 0:
                    return freed_total
                if attempt == 0:
                    self.stats.n_calls += 1
                progressed = False
                for sig, ent, density in self.ranked():
                    if deficit <= 0:
                        break
                    if (limit_density is not None
                            and density >= limit_density):
                        # Ascending order: every remaining candidate is
                        # at least this valuable — stop, don't evict
                        # better entries to admit a worse one.
                        break
                    if (self.live_multiplicity is not None
                            and self.live_multiplicity(sig)):
                        if attempt == 0:   # count each entry once per call
                            self.stats.n_vetoed_live += 1
                        continue
                    freed = self.store.delete(sig)  # lease-respecting
                    if freed <= 0:
                        # delete returns 0 both for a held lease and for
                        # an entry a concurrent session already removed;
                        # only the former is a lease *protection*.
                        if attempt == 0 and self.store.has(sig):
                            self.stats.n_skipped_leased += 1
                        continue
                    credit(freed)
                    if self.on_evict is not None:
                        try:
                            self.on_evict(sig, ent, freed)
                        except Exception:
                            pass
                    self.stats.n_evicted += 1
                    self.stats.bytes_evicted += freed
                    freed_total += freed
                    deficit -= freed
                    progressed = True
                if deficit <= 0:
                    return freed_total
                if not progressed:
                    break  # nothing evictable: don't spin on the index
            self.stats.n_unsatisfied += 1
            return freed_total
