"""Deterministic fault injection for the fleet substrate.

The remote tier's failure story (remote.py: marker-committed publishes,
TTL leases, degradation windows) is only credible if every protocol step
can be *made* to fail on demand, reproducibly. This module is that
harness:

* :class:`FaultPlan` — a seeded, scriptable schedule of faults: fail the
  Nth ``put``, fail a fraction of ``get``\\ s, add latency, drop
  heartbeat renewals, or crash a participant at a named protocol step
  (e.g. between "value uploaded" and "marker uploaded"). One plan is
  shared by every wrapper/handle participating in a scenario, so "the
  3rd put anywhere in the fleet" means exactly that.
* :class:`ChaosObjectStore` — an :class:`~repro.core.remote.ObjectStore`
  decorator that consults the plan *before* delegating each backend
  call. Faults therefore fire before the operation has any side effect,
  which keeps injected transient errors safe to retry — exactly the
  semantics a real backend's connection-refused / 503 has.
* :class:`InjectedCrash` — raised at an armed crash point.
  Deliberately a ``BaseException`` subclass: production code catches
  ``OSError`` (degrade) and ``Exception`` (job errors), and a simulated
  *process death* must sail through both and stop the participant where
  a ``kill -9`` would. Tests catch it at the scenario boundary.

:class:`~repro.core.remote.RemoteStore` accepts a plan via its
``faults=`` parameter and calls :meth:`FaultPlan.crash_point` at the
named steps of its publish/lease/heartbeat paths (the point names are
listed on that parameter's docstring); the heartbeat loop additionally
asks :meth:`FaultPlan.drop_heartbeat` before each renewal. Production
runs pass ``faults=None`` and pay a single ``is None`` check.

:class:`~repro.core.store.Store` consults a plan assigned to its
``faults`` attribute at the crash points of its multi-step local-disk
protocols: the chunked-splice publish path
(``splice:chunk_published``, ``splice:before_manifest``) and the
memory tier's demotion path (``memtier:before_spill`` dies before any
durable byte exists — a torn spill must be invisible after restart;
``memtier:after_spill`` dies with the disk entry committed and the
ledger already adjusted).

Error classes: ``error="transient"`` injects
:class:`~repro.core.remote.TransientBackendError` (retried with backoff
by the remote tier), ``error="permanent"`` injects a plain
:class:`OSError` (degrades the tier to local-only). A callable can be
passed instead to inject custom exceptions.

Everything is deterministic given the seed and the call order; the
``fired`` log records every injected fault so a failing chaos test can
print what actually happened.
"""
from __future__ import annotations

import random
import threading
import time

from .remote import ObjectStore, TransientBackendError

# Every backend operation the plan can target.
_OPS = ("put", "get", "list", "delete", "put_if_absent", "exists", "mtime")


class InjectedCrash(BaseException):
    """Simulated process death at a named crash point.

    A ``BaseException`` (not ``Exception``): the degradation handlers
    (``except OSError``) and job-error handlers must not absorb it —
    a crashed process doesn't degrade gracefully, it stops. Scenario
    code catches it where the "process boundary" of the simulated
    participant is.
    """


def _make_error(spec, op: str, key: str) -> BaseException:
    """Build the exception a rule injects (see module docstring)."""
    if callable(spec):
        return spec(op, key)
    if spec == "permanent":
        return OSError(f"injected permanent {op} failure on {key!r}")
    return TransientBackendError(
        f"injected transient {op} failure on {key!r}")


class _Rule:
    """One scripted failure: which ops/keys it matches and when it fires."""

    def __init__(self, op: str | None, *, error="transient",
                 nth: int | None = None, times: int = 1,
                 rate: float | None = None, key_substr: str | None = None):
        if op is not None and op not in _OPS:
            raise ValueError(f"unknown backend op {op!r}; one of {_OPS}")
        self.op = op                    # None matches every op
        self.error = error
        self.nth = nth                  # fire on the nth *matching* call
        self.remaining = int(times)     # how many times it may still fire
        self.rate = rate                # probabilistic instead of counted
        self.key_substr = key_substr
        self.seen = 0                   # matching calls observed so far

    def matches(self, op: str, key: str) -> bool:
        if self.op is not None and op != self.op:
            return False
        return self.key_substr is None or self.key_substr in key

    def should_fire(self, rng: random.Random) -> bool:
        """Called once per matching op (under the plan lock)."""
        if self.remaining <= 0:
            return False
        self.seen += 1
        if self.rate is not None:
            fire = rng.random() < self.rate
        else:
            fire = self.seen >= (self.nth or 1)
        if fire:
            self.remaining -= 1
        return fire


class FaultPlan:
    """A seeded, scriptable schedule of injected faults.

    Script it with :meth:`fail_nth` / :meth:`fail_rate` /
    :meth:`add_latency` / :meth:`crash_at` / :meth:`drop_heartbeats`,
    then hand it to a :class:`ChaosObjectStore` (backend faults) and/or
    a :class:`~repro.core.remote.RemoteStore` (crash points, heartbeat
    drops). All hooks are thread-safe; determinism holds whenever the
    cross-thread call order does (single-participant scenarios are
    bit-deterministic; storms are distribution-deterministic).
    """

    def __init__(self, seed: int = 0):
        """Create an empty plan; ``seed`` drives every random draw."""
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._rules: list[_Rule] = []
        self._latency: list[tuple[str | None, float, float]] = []
        self._crash_points: dict[str, list[int]] = {}  # name -> [nth, times]
        self._crash_seen: dict[str, int] = {}
        self._drop_heartbeats = 0
        #: Log of injected faults, in order: ``("error", op, key, kind)``,
        #: ``("latency", op, key, seconds)``, ``("crash", point)``,
        #: ``("heartbeat_drop",)`` — print it to reproduce a failure.
        self.fired: list[tuple] = []

    # -- scripting ---------------------------------------------------------
    def fail_nth(self, op: str | None, n: int = 1, *, error="transient",
                 times: int = 1, key_substr: str | None = None
                 ) -> "FaultPlan":
        """Fail the ``n``-th matching backend call (then ``times-1``
        more). ``op=None`` matches every operation; ``key_substr``
        narrows to keys containing it. Returns self for chaining."""
        with self._lock:
            self._rules.append(_Rule(op, error=error, nth=n, times=times,
                                     key_substr=key_substr))
        return self

    def fail_rate(self, op: str | None, rate: float, *, error="transient",
                  times: int = 10 ** 9, key_substr: str | None = None
                  ) -> "FaultPlan":
        """Fail each matching call with probability ``rate`` (seeded),
        at most ``times`` times in total. Returns self for chaining."""
        with self._lock:
            self._rules.append(_Rule(op, error=error, rate=float(rate),
                                     times=times, key_substr=key_substr))
        return self

    def add_latency(self, op: str | None, seconds: float,
                    jitter: float = 0.0) -> "FaultPlan":
        """Sleep ``seconds`` (+ uniform ``jitter``) before each matching
        backend call. Returns self for chaining."""
        with self._lock:
            self._latency.append((op, float(seconds), float(jitter)))
        return self

    def crash_at(self, point: str, nth: int = 1,
                 times: int = 1) -> "FaultPlan":
        """Arm a named crash point: the ``nth`` time a participant
        reaches ``point`` (see ``RemoteStore(faults=...)`` for the point
        names), :class:`InjectedCrash` is raised there — ``times`` times
        in total. Returns self for chaining."""
        with self._lock:
            self._crash_points[point] = [int(nth), int(times)]
            self._crash_seen.setdefault(point, 0)
        return self

    def drop_heartbeats(self, n: int = 1) -> "FaultPlan":
        """Skip the next ``n`` lease-heartbeat renewals (simulates a GC
        pause / CPU-starved heartbeat thread: the lease silently expires
        under a live holder). Returns self for chaining."""
        with self._lock:
            self._drop_heartbeats += int(n)
        return self

    # -- hooks (called by the chaos wrapper / RemoteStore) -----------------
    def on_op(self, op: str, key: str) -> None:
        """Consulted by :class:`ChaosObjectStore` before each delegated
        backend call: applies scripted latency, then raises the first
        matching armed error rule."""
        naps: list[float] = []
        err: BaseException | None = None
        with self._lock:
            for rule_op, seconds, jitter in self._latency:
                if rule_op is None or rule_op == op:
                    naps.append(seconds + (self._rng.random() * jitter
                                           if jitter else 0.0))
            for rule in self._rules:
                if rule.matches(op, key) and rule.should_fire(self._rng):
                    err = _make_error(rule.error, op, key)
                    self.fired.append(
                        ("error", op, key, type(err).__name__))
                    break
            if naps:
                self.fired.extend(("latency", op, key, s) for s in naps)
        for s in naps:      # sleep outside the lock
            time.sleep(s)
        if err is not None:
            raise err

    def crash_point(self, name: str) -> None:
        """Consulted by :class:`~repro.core.remote.RemoteStore` at each
        named protocol step; raises :class:`InjectedCrash` when the
        point is armed and its turn has come."""
        with self._lock:
            armed = self._crash_points.get(name)
            if armed is None:
                return
            nth, times = armed
            if times <= 0:
                return
            self._crash_seen[name] += 1
            if self._crash_seen[name] < nth:
                return
            armed[1] -= 1
            self.fired.append(("crash", name))
        raise InjectedCrash(f"injected crash at {name!r}")

    def drop_heartbeat(self) -> bool:
        """Consulted by the heartbeat loop before each renewal round;
        True means skip this renewal (scripted via
        :meth:`drop_heartbeats`)."""
        with self._lock:
            if self._drop_heartbeats <= 0:
                return False
            self._drop_heartbeats -= 1
            self.fired.append(("heartbeat_drop",))
            return True


class ChaosObjectStore(ObjectStore):
    """Fault-injecting decorator over any :class:`ObjectStore`.

    Consults the shared :class:`FaultPlan` *before* delegating, so an
    injected failure leaves the backend untouched (safe to retry — the
    semantics of a connection that died before the request landed).
    Stack it under a :class:`~repro.core.remote.RemoteStore` to exercise
    the tier's retry/degradation machinery::

        plan = FaultPlan(seed=7).fail_nth("put", 3).add_latency("get", 0.01)
        remote = RemoteStore(ChaosObjectStore(backend, plan), faults=plan)
    """

    def __init__(self, inner: ObjectStore, plan: FaultPlan):
        """Wrap ``inner``; every call consults (and logs to) ``plan``."""
        self.inner = inner
        self.plan = plan

    def put(self, key: str, data: bytes) -> None:
        """Delegated ``put`` behind the fault plan."""
        self.plan.on_op("put", key)
        return self.inner.put(key, data)

    def get(self, key: str) -> bytes | None:
        """Delegated ``get`` behind the fault plan."""
        self.plan.on_op("get", key)
        return self.inner.get(key)

    def list(self, prefix: str) -> list[str]:
        """Delegated ``list`` behind the fault plan."""
        self.plan.on_op("list", prefix)
        return self.inner.list(prefix)

    def delete(self, key: str) -> bool:
        """Delegated ``delete`` behind the fault plan."""
        self.plan.on_op("delete", key)
        return self.inner.delete(key)

    def put_if_absent(self, key: str, data: bytes) -> bool:
        """Delegated conditional put behind the fault plan."""
        self.plan.on_op("put_if_absent", key)
        return self.inner.put_if_absent(key, data)

    def exists(self, key: str) -> bool:
        """Delegated presence probe behind the fault plan."""
        self.plan.on_op("exists", key)
        return self.inner.exists(key)

    def mtime(self, key: str) -> float | None:
        """Delegated mtime probe behind the fault plan."""
        self.plan.on_op("mtime", key)
        return self.inner.mtime(key)
