"""Memory tier of the TierStack (memory → disk → remote).

The store's two durable tiers (disk, remote) round-trip every value
through ``.npy`` — serialization that caps iteration latency exactly
where the paper's sub-second feedback loop matters. This module adds the
tier that was missing: a bounded host-RAM cache of materialized values
held as **zero-copy pytrees** (``np.ndarray`` / ``jax.Array`` leaves are
referenced, never serialized), sitting in front of the disk tier behind
the same signature-keyed API.

Semantics:

* **Read-through promotion** — every disk/remote load publishes its
  value here, so the next same-process load of that signature is a
  dictionary lookup: no ``.npy`` read, no unpickle, no host copy.
* **Write-through** — a publish to disk admits its (already snapshotted)
  host pytree here for free; ``save_enqueue`` admits *before* the disk
  write lands (state ``"queued"``), so in-process reuse never waits on
  the writer thread.
* **Demote-not-delete eviction** — the budget is enforced by *demotion*,
  ranked by :func:`~repro.core.eviction.ranked_mem`: an entry the disk
  tier already holds (``"durable"``/``"queued"``) demotes by dropping
  the RAM reference (the value survives one tier down at one disk-reload
  of cost); a ``"dirty"`` entry (memory-only, write-back mode) is first
  *spilled* to disk through the owning store's spill hook — which runs
  the ``memtier:before_spill`` / ``memtier:after_spill`` crash points —
  and only then dropped.
* **Async device offload** — values admitted with ``jax.Array`` leaves
  (sharded loads) are handed to the store's writer-queue machinery to be
  snapshotted to host RAM off the critical path; until the offload runs
  the device arrays are served as-is (zero-copy either way).

Entry states:

``"durable"``
    A committed disk copy exists; demotion is a drop.
``"queued"``
    The disk write is owned by the store's writer queue (which holds its
    own reference to the host pytree); dropping here loses nothing.
``"dirty"``
    Memory-only (write-back mode). Demotion must spill first; a crash
    before the spill loses the entry — recovery is a clean recompute
    (the signature was never visible to any other process).

The per-tier ledger invariant mirrors the disk tier's ``ledger == disk``:
:attr:`MemTier.bytes_held` (maintained transactionally with every
admit/drop) always equals :meth:`MemTier.recount` (the ground-truth sum
over resident entries). ``tier_status()`` surfaces both via the unified
per-tier record (name, bytes, budget, entries, leases, hits, misses).

The tier is deliberately **process-local**: cross-process coherence is
the disk tier's job (entry locks, leases, the fleet ledger). Because
entries are content-addressed by signature, a resident value can never
be *stale* — at worst it is a copy of something another process deleted,
which is a budget question, not a correctness one (``Store.delete``
drops the resident copy anyway, so tiers never disagree for long).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable

import numpy as np

import jax

from .eviction import ranked_mem

# Distinguishes "miss" from a legitimately-None cached value.
MISS = object()


class MemEntry:
    """One resident value (slots: this sits on the hot hit path)."""

    __slots__ = ("value", "nbytes", "name", "meta", "state", "loads",
                 "last_load", "created", "has_device")

    def __init__(self, value: Any, nbytes: int, name: str, meta: dict,
                 state: str):
        self.value = value
        self.nbytes = int(nbytes)
        self.name = name
        self.meta = dict(meta)
        self.state = state              # "durable" | "queued" | "dirty"
        self.loads = 0
        self.last_load = 0.0
        self.created = time.time()
        self.has_device = any(
            isinstance(leaf, jax.Array) and not isinstance(leaf, np.ndarray)
            for leaf in jax.tree_util.tree_leaves(value))


class MemTier:
    """Bounded host-RAM tier of one :class:`~repro.core.store.Store`.

    ``spill(sig, entry)`` persists a dirty entry to the disk tier (the
    store wires its own lock-safe save path, with crash points);
    ``offload(sig)`` schedules an async device→host snapshot of a
    resident entry on the store's writer queue; ``writeback=True`` makes
    the store's saves land here *instead of* disk (demotion becomes the
    write-back point). All three are optional — a bare tier is a plain
    bounded promotion cache.
    """

    def __init__(self, budget_bytes: float, *, writeback: bool = False,
                 spill: Callable[[str, MemEntry], None] | None = None,
                 offload: Callable[[str], None] | None = None,
                 est_disk_load: Callable[[float], float] | None = None):
        self.budget_bytes = float(budget_bytes)
        self.writeback = bool(writeback)
        self._spill = spill
        self._offload = offload
        self._est_disk_load = est_disk_load or (lambda nb: nb / 500e6 + 1e-4)
        self._lock = threading.Lock()
        self._entries: dict[str, MemEntry] = {}
        self._bytes = 0                 # the per-tier ledger
        # Observability (tier_status schema: hits/misses + tier actions).
        self.hits = 0
        self.misses = 0
        self.hit_bytes = 0
        self.demotions = 0              # durable/queued drops under pressure
        self.spills = 0                 # dirty entries written back to disk
        self.offloads = 0               # async device→host snapshots run

    # -- admission / demotion ----------------------------------------------
    def put(self, sig: str, value: Any, nbytes: int, *, name: str = "",
            meta: dict | None = None, state: str = "durable") -> bool:
        """Admit (or replace) ``sig``; demote the cheapest residents to
        fit the budget. Returns False when the value alone exceeds the
        whole budget (nothing is admitted or demoted then). The new
        entry ranks with everything else — admitting it may immediately
        demote it if it is the least valuable resident."""
        nbytes = int(nbytes)
        if nbytes > self.budget_bytes or self.budget_bytes <= 0:
            return False
        victims: list[tuple[str, MemEntry]] = []
        with self._lock:
            old = self._entries.pop(sig, None)
            if old is not None:
                self._bytes -= old.nbytes
            ent = MemEntry(value, nbytes, name, meta or {}, state)
            if old is not None:
                # Same signature ⇒ same value: carry the reuse evidence
                # (and never let a re-admit weaken durability to the
                # point of forgetting an existing disk copy).
                ent.loads, ent.last_load = old.loads, old.last_load
                if old.state == "durable" and state == "queued":
                    ent.state = "durable"
            self._entries[sig] = ent
            self._bytes += nbytes
            if self._bytes > self.budget_bytes:
                victims = self._pick_victims_locked(
                    self._bytes - self.budget_bytes)
        for vsig, vent in victims:
            self._demote(vsig, vent)
        if ent.has_device and self._offload is not None:
            self._offload(sig)
        with self._lock:
            return sig in self._entries

    def _pick_victims_locked(self, deficit: float
                             ) -> list[tuple[str, MemEntry]]:
        """Remove (and return) the cheapest-to-demote entries covering
        ``deficit`` bytes. Runs under the tier lock; the actual demotion
        work (spills do store I/O) happens outside it."""
        snapshot = {
            sig: {"nbytes": e.nbytes, "loads": e.loads,
                  "last_load": e.last_load, "created": e.created,
                  "dirty": e.state == "dirty",
                  "compute_s": float(e.meta.get("compute_s", 0.0) or 0.0)}
            for sig, e in self._entries.items()}
        victims: list[tuple[str, MemEntry]] = []
        for sig in ranked_mem(snapshot, self._est_disk_load):
            if deficit <= 0:
                break
            ent = self._entries.pop(sig)
            self._bytes -= ent.nbytes
            deficit -= ent.nbytes
            victims.append((sig, ent))
        return victims

    def _demote(self, sig: str, ent: MemEntry) -> None:
        """Demote one already-removed entry: spill if dirty, else drop
        (the cheap action — a durable/queued entry survives one tier
        down). A spill crash (InjectedCrash) propagates: the simulated
        participant died mid-demotion."""
        if ent.state == "dirty" and self._spill is not None:
            self.spills += 1
            self._spill(sig, ent)
        else:
            self.demotions += 1

    # -- lookups -----------------------------------------------------------
    def get(self, sig: str) -> MemEntry | None:
        """Hit path: the resident entry (bumping reuse evidence and hit
        counters) or None. Zero-copy — the caller gets the stored pytree
        itself, under the store-wide convention that materialized values
        are immutable."""
        with self._lock:
            ent = self._entries.get(sig)
            if ent is None:
                self.misses += 1
                return None
            ent.loads += 1
            ent.last_load = time.time()
            self.hits += 1
            self.hit_bytes += ent.nbytes
            return ent

    def peek(self, sig: str) -> MemEntry | None:
        """Lookup without touching hit/reuse counters (bookkeeping)."""
        with self._lock:
            return self._entries.get(sig)

    def has(self, sig: str) -> bool:
        """Is ``sig`` resident (any state)?"""
        with self._lock:
            return sig in self._entries

    def drop(self, sig: str) -> bool:
        """Remove ``sig`` without demotion (e.g. the store deleted the
        entry fleet-wide). Returns True when something was resident."""
        with self._lock:
            ent = self._entries.pop(sig, None)
            if ent is not None:
                self._bytes -= ent.nbytes
            return ent is not None

    def clear(self) -> None:
        """Drop everything (tests / benchmarks isolating the disk tier)."""
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    def mark_durable(self, sig: str) -> None:
        """Record that a committed disk copy now exists for ``sig``."""
        with self._lock:
            ent = self._entries.get(sig)
            if ent is not None:
                ent.state = "durable"

    def replace_value(self, sig: str, value: Any, expect: Any) -> bool:
        """Swap a resident entry's value (the async device→host offload
        landing) — only if the entry still holds exactly the pytree the
        offload snapshotted (``expect``), so a racing re-admit wins."""
        with self._lock:
            ent = self._entries.get(sig)
            if ent is None or ent.value is not expect:
                return False
            ent.value = value
            ent.has_device = False
        self.offloads += 1
        return True

    def flush(self) -> int:
        """Write-back barrier: spill every dirty entry to disk (keeping
        it resident as ``"durable"``). Returns the number spilled."""
        with self._lock:
            dirty = [(sig, ent) for sig, ent in self._entries.items()
                     if ent.state == "dirty"]
        n = 0
        for sig, ent in dirty:
            if self._spill is not None:
                self.spills += 1
                self._spill(sig, ent)
            self.mark_durable(sig)
            n += 1
        return n

    def dirty_sigs(self) -> list[str]:
        """Signatures resident only in memory (write-back entries)."""
        with self._lock:
            return [sig for sig, ent in self._entries.items()
                    if ent.state == "dirty"]

    # -- ledger / observability --------------------------------------------
    @property
    def bytes_held(self) -> int:
        """The tier ledger: bytes admitted minus bytes demoted/dropped."""
        with self._lock:
            return self._bytes

    def recount(self) -> int:
        """Ground truth for the ledger invariant: sum over residents."""
        with self._lock:
            return sum(e.nbytes for e in self._entries.values())

    def status(self) -> dict:
        """Unified per-tier record (same schema as the disk/remote tiers
        in ``Store.tier_status``: name, bytes, budget, entries, leases,
        hits, misses — plus this tier's demotion/spill/offload counts)."""
        with self._lock:
            n_dirty = sum(1 for e in self._entries.values()
                          if e.state == "dirty")
            return {
                "name": "memory",
                "bytes": self._bytes,
                "budget": self.budget_bytes,
                "entries": len(self._entries),
                # Memory is process-local: nothing fleet-visible to lease.
                "leases": {"compute": 0, "pins": 0, "waiters": 0},
                "hits": self.hits,
                "misses": self.misses,
                "hit_bytes": self.hit_bytes,
                "dirty": n_dirty,
                "demotions": self.demotions,
                "spills": self.spills,
                "offloads": self.offloads,
            }
