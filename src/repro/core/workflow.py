"""The Helix-JAX workflow DSL (the HML analogue, paper §3).

HML's operator interfaces map one-to-one:

    HML                  Helix-JAX
    -------------------  -------------------------------
    data source          Workflow.source(...)
    Scanner              Workflow.scanner(...)
    Extractor            Workflow.extractor(...)
    Synthesizer          Workflow.synthesizer(...)
    Learner              Workflow.learner(...)
    Reducer              Workflow.reducer(...)
    A results_from B     inputs=[B]
    A uses (e1, e2)      uses=[e1, e2]   (extra edges, UDF deps — §5.4)
    A is_output          wf.output(A)
    training segment     Workflow.segment(...)  (Helix-JAX extension)

Versions: the ``version`` of a node is derived from its config blob via
``source_version`` — editing a hyperparameter automatically deprecates the
node and (through recursive signatures) its descendants, which is exactly the
paper's representational-equivalence change tracking.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

from .dag import DAG, Kind, Node
from .signature import source_version


class Ref:
    """Handle to a declared node; usable as an input to later declarations."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Ref({self.name})"


def _names(items: Iterable) -> tuple[str, ...]:
    out = []
    for it in items or ():
        out.append(it.name if isinstance(it, Ref) else str(it))
    return tuple(out)


class Workflow:
    def __init__(self, name: str):
        self.name = name
        self._nodes: list[Node] = []
        self._outputs: set[str] = set()

    # -- generic declaration -----------------------------------------------------
    def node(self, name: str, fn: Callable, inputs: Iterable = (),
             kind: Kind = Kind.EXTRACTOR, config: Any = None,
             uses: Iterable = (), deterministic: bool = True,
             cost_hint: float | None = None,
             incremental: str | None = None,
             chunk_ids: tuple[str, ...] | None = None) -> Ref:
        """Declare one operator output.

        ``incremental`` declares how the operator transforms per-chunk —
        ``"map"`` (row-local), ``"union"`` (row-concat of its parents) or
        ``"assoc_reduce"`` (chunk → partial, partials combine
        associatively) — enabling chunk-granular reuse on data deltas
        (see chunks.py for the exact contracts). ``None`` (default)
        keeps the operator opaque: any input change recomputes it whole.
        """
        if incremental not in (None, "map", "union", "assoc_reduce"):
            raise ValueError(
                f"{name}: incremental={incremental!r} is not one of "
                "'map', 'union', 'assoc_reduce', None")
        parents = _names(inputs) + _names(uses)
        self._nodes.append(Node(
            name=name, fn=fn, parents=parents, kind=kind,
            version=source_version(config),
            deterministic=deterministic, cost_hint=cost_hint,
            incremental=incremental,
            chunk_ids=tuple(chunk_ids) if chunk_ids else None))
        return Ref(name)

    # -- HML-style sugar -----------------------------------------------------------
    def source(self, name, fn, config=None, chunks=None, **kw) -> Ref:
        """Declare a data source. ``chunks`` (an iterable of per-chunk
        descriptors, e.g. ``[(seed, n_rows), ...]``) declares an
        append-mostly *chunked* source: ``fn`` must then return one value
        per descriptor (a list), ``config`` defaults to the descriptor
        tuple, and each chunk's identity is the hash of its descriptor —
        so appending a batch leaves the existing chunks' identities (and
        downstream chunk signatures) intact."""
        if chunks is not None:
            chunks = tuple(chunks)
            if config is None:
                config = chunks
            kw = dict(kw, chunk_ids=tuple(source_version(c)
                                          for c in chunks))
        return self.node(name, fn, (), Kind.SOURCE, config, **kw)

    def scanner(self, name, fn, inputs, config=None, **kw) -> Ref:
        return self.node(name, fn, inputs, Kind.SCANNER, config, **kw)

    def extractor(self, name, fn, inputs, config=None, **kw) -> Ref:
        return self.node(name, fn, inputs, Kind.EXTRACTOR, config, **kw)

    def synthesizer(self, name, fn, inputs, config=None, **kw) -> Ref:
        return self.node(name, fn, inputs, Kind.SYNTHESIZER, config, **kw)

    def learner(self, name, fn, inputs, config=None, **kw) -> Ref:
        return self.node(name, fn, inputs, Kind.LEARNER, config, **kw)

    def reducer(self, name, fn, inputs, config=None, **kw) -> Ref:
        return self.node(name, fn, inputs, Kind.REDUCER, config, **kw)

    def segment(self, name, fn, inputs, config=None, **kw) -> Ref:
        """A fault-tolerance unit: N optimizer steps as one reusable node."""
        return self.node(name, fn, inputs, Kind.SEGMENT, config, **kw)

    def output(self, ref: Ref) -> Ref:
        self._outputs.add(ref.name)
        return ref

    # -- compilation -----------------------------------------------------------------
    def build(self) -> DAG:
        nodes = []
        for n in self._nodes:
            if n.name in self._outputs:
                import dataclasses
                n = dataclasses.replace(n, is_output=True)
            nodes.append(n)
        return DAG(nodes)
