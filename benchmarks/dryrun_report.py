"""§Dry-run report: one row per (arch × shape × mesh) from results/dryrun.

Proves the distribution config is coherent: lower+compile success on the
16×16 pod and the 2×16×16 two-pod mesh, bytes-per-device, and the compiled
collective schedule (op counts + wire bytes).
"""
from __future__ import annotations

import glob
import json
import os


def rows(dirname: str = "results/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        ma = r.get("memory_analysis")
        temp = (ma.get("temp_size_in_bytes", 0) if isinstance(ma, dict)
                else float("nan"))
        coll = r.get("collectives", {})
        counts = coll.get("counts", {})
        wire = sum(coll.get("wire_bytes", {}).values())
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "ok": r.get("ok", False),
            "compile_s": r.get("compile_s", float("nan")),
            "arg_gb": r.get("arg_bytes_per_device", 0) / 1e9,
            "temp_gb": temp / 1e9,
            "wire_gb": wire / 1e9,
            "n_coll": sum(counts.values()),
            "counts": counts,
        })
    return out


def markdown(dirname: str = "results/dryrun") -> str:
    hdr = ("| arch | shape | mesh | ok | compile s | args GB/dev | "
           "temp GB/dev | collectives (AR/AG/RS/A2A/CP) | wire GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows(dirname):
        c = r["counts"]
        cs = "/".join(str(c.get(k, 0)) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {'✓' if r['ok'] else '✗'} | {r['compile_s']:.1f} "
            f"| {r['arg_gb']:.2f} | {r['temp_gb']:.1f} | {cs} "
            f"| {r['wire_gb']:.2f} |")
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(markdown(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"))
