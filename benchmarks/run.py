"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  bench_cumulative_runtime  — paper Fig. 5 / Fig. 9(a,b,e,f): cumulative
      runtime over 10 iterations for each workflow under OPT / AM / NM
      (NM ≈ KeystoneML's materialize-nothing; AM ≈ DeepDive's
      materialize-everything).
  bench_storage             — paper Fig. 9(c,d): store size after the runs.
  bench_state_fractions     — paper Fig. 8: prune/load/compute fractions,
      OPT vs AM (OPT should match AM's reuse without AM's storage).
  bench_optimizer_overhead  — OEP max-flow solve time vs DAG size (the
      optimizer must be negligible next to operator runtimes).
  bench_parallel_speedup    — sequential engine (max_workers=1, the paper's
      §5.3 discipline) vs the pipelined ready-set engine (worker pool +
      LOAD prefetch + async writer queue) on workflows with branch
      parallelism, reported next to the Fig. 5 numbers.
  bench_sweep_reuse         — ISSUE 2: a K-variant hyperparameter sweep
      sharing one store (concurrent sessions, in-flight dedupe, shared
      budget ledger) vs. K isolated cold runs, on census and MNIST.
      Also verifies no shared-prefix signature was computed twice.
  bench_server_reuse        — ISSUE 3: the session server's global
      shared-prefix-first schedule vs. PR 2's lease-contention FIFO at
      equal concurrency (K variants, K/2 session slots).
  bench_eviction            — ISSUE 4: evict-to-admit vs
      refuse-on-exhausted at a budget ~50% of the sweep working set,
      store pre-squatted by stale junk; also checks ledger==disk at
      drain.
  bench_remote_reuse        — ISSUE 5: cold-host speedup from a warm
      remote tier (fleet-wide materialization sharing across hosts) on
      the census grid: a 2-host sweep warms the tier (fleet compute-once
      must hold across hosts), then a fresh "host" runs the same grid
      against the warm tier vs. an empty one.
  bench_search_reuse        — ISSUE 7: the reuse-aware SearchDriver vs a
      fixed-batch FIFO sweep at equal arm count on the census grid (the
      tuner's marginal-cost frontier must compute measurably fewer
      nodes), plus a successive-halving run whose early-stopped arms
      must leave zero ledger drift and zero wasted recomputes.
  bench_incremental         — ISSUE 8: daily retrain on an append-mostly
      chunked census source: a 10 % append's spliced delta iteration
      must land under 0.5x the cold full retrain, bit-identically
      (writes results/bench/incremental.csv).
  bench_tier                — ISSUE 9: the store's memory tier on the LM
      training workflow: a warm same-process rerun must serve ≥90 % of
      reused bytes from host RAM with zero ``.npy`` leaf reads on the
      hit path, bit-identically to the cold run; a memory hit must load
      ≥5x faster than a disk reload of the same signature; per-tier
      ledgers must equal bytes held after the runs.
  bench_multitenant         — ISSUE 10: consistent-hash (prefix-affine)
      routing vs seeded-random placement across a 2-shard fleet on
      warm-shard reruns: hash routing must land every repeat submission
      on the shard already holding its prefix (0 recomputes, asserted),
      random placement recomputes prefixes on cold shards; the row
      reports the wall-clock speedup (acceptance bar ≥ 1.3x).

Env knobs: HELIX_BENCH_ITERS (default 10), HELIX_BENCH_WORKFLOWS (csv list),
HELIX_BENCH_PAR_WORKERS (worker-pool width for the pipelined engine),
HELIX_BENCH_SWEEP_VARIANTS (sweep arms, default 4), HELIX_BENCH_SWEEP_SCALE
(input-size scale for the sweep bench, default 1 — CI smoke uses ~0.05),
HELIX_BENCH_LM_STEPS / HELIX_BENCH_LM_DM (bench_tier LM train steps and
d_model, defaults 4 / 128), HELIX_BENCH_TENANT_FAMILIES
(bench_multitenant workflow families, default 6).
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import sys
import time
from concurrent.futures import ThreadPoolExecutor

# Pin BLAS to one thread *before* numpy loads: the speedup benchmark
# measures engine-level branch parallelism, which double-counts if BLAS
# also fans out every matmul internally. Applies equally to both engines.
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.dirname(__file__))

from repro.core import IterativeSession, Policy  # noqa: E402
from repro.core.dag import DAG, Node             # noqa: E402
from repro.core import oep                       # noqa: E402

import workflows as W                            # noqa: E402

N_ITERS = int(os.environ.get("HELIX_BENCH_ITERS", "10"))
SELECT = os.environ.get("HELIX_BENCH_WORKFLOWS", "census,genomics,nlp,mnist"
                        ).split(",")
BUDGET = 10 * 1024 ** 3    # paper §6.3: 10 GB storage budget
ROOT = os.path.join(os.path.dirname(__file__), os.pardir,
                    "results", "bench")


def _run_policy(wd: W.WorkflowDef, policy: Policy, seed: int = 0):
    """Run N_ITERS iterations; returns (per-iter seconds, reports)."""
    workdir = os.path.join(ROOT, f"{wd.name}_{policy.value}")
    shutil.rmtree(workdir, ignore_errors=True)
    sess = IterativeSession(workdir, policy=policy,
                            storage_budget_bytes=BUDGET)
    knobs = W.iteration_schedule(wd, N_ITERS, seed)
    times, reports = [], []
    for kn in knobs:
        wf = wd.build(kn)
        t0 = time.perf_counter()
        rep = sess.run(wf)
        times.append(time.perf_counter() - t0)
        reports.append(rep)
    return times, reports


_CACHE: dict = {}


def _results(wd: W.WorkflowDef, policy: Policy):
    key = (wd.name, policy)
    if key not in _CACHE:
        _CACHE[key] = _run_policy(wd, policy)
    return _CACHE[key]


def bench_cumulative_runtime() -> None:
    """Fig. 5 / 9: cumulative runtime per workflow per policy."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        cum = {}
        for policy in (Policy.NEVER, Policy.ALWAYS, Policy.OPT):
            times, _ = _results(wd, policy)
            cum[policy] = sum(times)
        for policy, total in cum.items():
            speedup = cum[Policy.NEVER] / max(total, 1e-9)
            print(f"{name}_{policy.value}_cumulative,"
                  f"{total * 1e6 / N_ITERS:.0f},"
                  f"total_s={total:.2f};speedup_vs_nm={speedup:.2f}x",
                  flush=True)


def bench_storage() -> None:
    """Fig. 9(c,d): storage snapshots."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        for policy in (Policy.ALWAYS, Policy.OPT):
            _, reports = _results(wd, policy)
            final = reports[-1].store_bytes
            peak = max(r.store_bytes for r in reports)
            print(f"{name}_{policy.value}_storage,"
                  f"{final / 1024:.0f},"
                  f"peak_kb={peak / 1024:.0f}", flush=True)


def bench_state_fractions() -> None:
    """Fig. 8: aggregate state distribution across reuse iterations."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        for policy in (Policy.OPT, Policy.ALWAYS):
            _, reports = _results(wd, policy)
            comp = sum(r.execution.n_computed for r in reports[1:])
            load = sum(r.execution.n_loaded for r in reports[1:])
            prune = sum(r.execution.n_pruned for r in reports[1:])
            tot = max(comp + load + prune, 1)
            print(f"{name}_{policy.value}_states,"
                  f"{comp},"
                  f"compute={comp / tot:.2f};load={load / tot:.2f};"
                  f"prune={prune / tot:.2f}", flush=True)


def bench_optimizer_overhead() -> None:
    """OEP (max-flow) solve time vs DAG size."""
    rng = np.random.default_rng(0)
    for n in (50, 200, 1000):
        nodes = []
        for i in range(n):
            k = int(min(i, 3))
            parents = tuple(f"n{j}" for j in
                            rng.choice(i, k, replace=False)) if i else ()
            nodes.append(Node(name=f"n{i}", fn=None, parents=parents,
                              is_output=(i == n - 1)))
        dag = DAG(nodes)
        cc = {f"n{i}": float(rng.uniform(0.1, 10)) for i in range(n)}
        lc = {f"n{i}": (float(rng.uniform(0.1, 5))
                        if rng.random() < 0.7 else None) for i in range(n)}
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            oep.plan(dag, cc, lc, original=set())
        dt = (time.perf_counter() - t0) / reps
        print(f"oep_solver_n{n},{dt * 1e6:.0f},nodes={n}", flush=True)


def bench_parallel_speedup() -> None:
    """Sequential vs pipelined engine, wall clock of execute().

    census exercises the paper's Fig. 3 parallel feature extractors;
    mnist runs with 12 independent random-FFT towers (KeystoneML-style
    block featurization + per-tower heads). Each engine runs the same
    3-iteration schedule (cold start + two edits) on a fresh store.
    """
    n_workers = int(os.environ.get("HELIX_BENCH_PAR_WORKERS",
                                   str(max(2, os.cpu_count() or 2))))
    n_iters = 3
    cases = {
        "census": (W.WORKFLOWS["census"], {}),
        # Tower ensemble (KeystoneML block solve): 12 independent
        # fft→head→logits branches. PPR-only edits keep the tower shape
        # stable across the schedule (towers are nondeterministic, so every
        # iteration re-runs the full fan-out — the branch-parallel hot
        # path this benchmark isolates). NOTE: attainable speedup is capped
        # by the host — on SMT-sibling vCPU pairs, FP-SIMD numpy work
        # scales at best ~1.4x even fully parallel; on >=4 distinct cores
        # the tower fan-out exceeds 1.5-2x.
        "mnist": (W.WORKFLOWS["mnist"],
                  dict(knobs0=dataclasses.replace(
                           W.MNISTKnobs(), n_towers=12, n_features=6144,
                           n_images=8000, epochs=4),
                       freqs={"PPR": 1.0})),
    }
    for name, (wd, overrides) in cases.items():
        if overrides:
            wd = dataclasses.replace(wd, **overrides)
        engine_secs = {}
        for mode, workers in (("seq", 1), ("par", n_workers)):
            workdir = os.path.join(ROOT, f"{name}_speedup_{mode}")
            shutil.rmtree(workdir, ignore_errors=True)
            sess = IterativeSession(
                workdir, policy=Policy.OPT, storage_budget_bytes=BUDGET,
                max_workers=workers, prefetch_depth=8,
                async_materialization=(workers > 1))
            secs = 0.0
            for kn in W.iteration_schedule(wd, n_iters, seed=0):
                rep = sess.run(wd.build(kn))
                secs += rep.execution.total_seconds
            engine_secs[mode] = secs
        speedup = engine_secs["seq"] / max(engine_secs["par"], 1e-9)
        print(f"{name}_parallel_speedup,"
              f"{engine_secs['par'] * 1e6 / n_iters:.0f},"
              f"seq_s={engine_secs['seq']:.2f};par_s={engine_secs['par']:.2f};"
              f"workers={n_workers};speedup={speedup:.2f}x", flush=True)


def bench_sweep_reuse() -> None:
    """K-variant sweep, one shared store vs. K isolated cold runs.

    The isolated baseline runs each variant in its own fresh workdir (no
    cross-variant reuse possible — today's "fleet" of independent Helix
    users) with the SAME concurrency as the sweep, so the headline
    speedup isolates reuse rather than thread parallelism (the
    sequential sum is also reported as iso_seq_s for reference). The
    sweep runs all K against one store: the max-flow planner + in-flight
    dedupe turn every shared prefix into one compute and K-1 loads.
    census shares everything up to example assembly; MNIST shares the
    random-FFT featurization via the sweep's pinned nonces (one draw for
    the whole sweep).
    """
    from repro.core import IterativeSession, grid, run_sweep

    n_var = int(os.environ.get("HELIX_BENCH_SWEEP_VARIANTS", "4"))
    sweep_scale = float(os.environ.get("HELIX_BENCH_SWEEP_SCALE", "1"))
    # Grid axes: a learner knob × a result-analysis (PPR) knob. Variants
    # then share prefixes *hierarchically* — every arm shares the data
    # pipeline, arms with equal learner knobs also share the trained model
    # (the Li et al. 2019 pipeline-aware-tuning structure). The learner
    # axis gets ⌈K/2⌉ values, the PPR axis 2.
    regs = [0.03, 0.3, 0.01, 1.0, 0.1, 3.0]
    n_regs = max(1, (n_var + 1) // 2)
    cases = {
        "census": (W.CensusKnobs(n_rows=max(2000,
                                            int(120_000 * sweep_scale))),
                   W.build_census,
                   {"reg": regs[:n_regs], "eval_threshold": [0.5, 0.7]}),
        "mnist": (W.MNISTKnobs(n_images=max(500,
                                            int(12_000 * sweep_scale)),
                               epochs=max(5, int(60 * sweep_scale))),
                  W.build_mnist,
                  {"reg": [r * 1e-2 for r in regs[:n_regs]],
                   "eval_k": [1, 2]}),
    }
    for name, (base, build, axes) in cases.items():
        variants = grid(base, axes, build, name=name)[:n_var]
        knob_list = [v.knobs for v in variants]
        n_eff = len(variants)   # the axes can yield fewer arms than asked
        if n_eff < n_var:
            print(f"# {name}: {n_var} variants requested, grid yields "
                  f"{n_eff}", flush=True)

        def run_isolated(i_kn):
            i, kn = i_kn
            workdir = os.path.join(ROOT, f"{name}_sweep_iso{i}")
            shutil.rmtree(workdir, ignore_errors=True)
            sess = IterativeSession(workdir, storage_budget_bytes=BUDGET)
            t0 = time.perf_counter()
            sess.run(build(kn))
            return time.perf_counter() - t0

        iso_seq = sum(run_isolated(ik) for ik in enumerate(knob_list))
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=n_eff) as pool:
            list(pool.map(run_isolated, enumerate(knob_list)))
        iso_par = time.perf_counter() - t0

        workdir = os.path.join(ROOT, f"{name}_sweep_shared")
        shutil.rmtree(workdir, ignore_errors=True)
        report = run_sweep(workdir, variants,
                           storage_budget_bytes=BUDGET)
        report.raise_errors()
        # fleet-wide compute-once check on shared signatures: coordination
        # failures only (deliberate recompute-cheaper-than-load planner
        # choices are reuse economics, not missed reuse)
        shared_recomputed = report.wasted_recomputes()
        speedup = iso_par / max(report.wall_seconds, 1e-9)
        print(f"{name}_sweep_reuse,"
              f"{report.wall_seconds * 1e6 / n_eff:.0f},"
              f"iso_par_s={iso_par:.2f};iso_seq_s={iso_seq:.2f};"
              f"sweep_s={report.wall_seconds:.2f};"
              f"variants={n_eff};speedup={speedup:.2f}x;"
              f"shared_recomputed={shared_recomputed};"
              f"store_kb={report.store_bytes / 1024:.0f}", flush=True)


def bench_server_reuse() -> None:
    """ISSUE 3: the session server's shared-prefix-first global schedule
    vs. PR 2's lease-contention-only dispatch, at equal concurrency.

    Both paths run the same K-variant grid against one shared store
    through ``run_sweep`` (now a session-server client) with
    ``n_concurrent = K/2`` session slots — the many-users-few-slots
    regime where dispatch order matters. The baseline pins
    ``schedule="fifo"`` + ``horizon=K`` (PR 2's behavior: arrival-order
    dispatch, siblings coordinate by blocking on compute leases, static
    amortization); the server path uses ``schedule="prefix"`` with live
    multiplicity-driven amortization. Variants are submitted in natural
    grid order (siblings adjacent) — the common case and FIFO's worst:
    it burns session slots on lease waits that the global scheduler
    instead fills with independent arms.

    Compute-once must hold in both modes: ``shared_recomputed`` counts
    *coordination failures* (a shared value recomputed although loading
    it was the better plan — must be 0; see
    ``SweepReport.wasted_recomputes``). ``planner_recomputed`` counts
    signatures duplicated *on purpose* because the max-flow planner
    priced recompute below load (tiny extractors) — that is reuse
    economics, not missed reuse; PR 2's lease-blocked siblings loaded
    such values blindly. The headline is pure wall clock.

    Regime note: the ordering win needs session slots ≈ cores. With more
    CPU-bound slots than physical cores, every slot is contended anyway,
    a lease-wait costs nothing, and dispatch order stops mattering —
    keep HELIX_BENCH_SWEEP_VARIANTS/2 near the host's core count.
    """
    from repro.core import grid, run_sweep

    n_var = int(os.environ.get("HELIX_BENCH_SWEEP_VARIANTS", "4"))
    sweep_scale = float(os.environ.get("HELIX_BENCH_SWEEP_SCALE", "1"))
    regs = [0.03, 0.3, 0.01, 1.0, 0.1, 3.0]
    n_regs = max(1, (n_var + 1) // 2)
    cases = {
        "census": (W.CensusKnobs(n_rows=max(2000,
                                            int(120_000 * sweep_scale))),
                   W.build_census,
                   {"reg": regs[:n_regs], "eval_threshold": [0.5, 0.7]}),
        "mnist": (W.MNISTKnobs(n_images=max(500,
                                            int(12_000 * sweep_scale)),
                               epochs=max(5, int(60 * sweep_scale))),
                  W.build_mnist,
                  {"reg": [r * 1e-2 for r in regs[:n_regs]],
                   "eval_k": [1, 2]}),
    }
    for name, (base, build, axes) in cases.items():
        variants = grid(base, axes, build, name=name)[:n_var]
        n_eff = len(variants)
        n_conc = max(2, n_eff // 2)
        walls = {}
        wasted = {}
        deliberate = {}
        for mode in ("fifo", "prefix"):
            workdir = os.path.join(ROOT, f"{name}_server_{mode}")
            shutil.rmtree(workdir, ignore_errors=True)
            report = run_sweep(
                workdir, variants, n_concurrent=n_conc,
                storage_budget_bytes=BUDGET, schedule=mode,
                horizon=float(n_eff) if mode == "fifo" else None)
            report.raise_errors()
            walls[mode] = report.wall_seconds
            wasted[mode] = report.wasted_recomputes()
            deliberate[mode] = sum(
                1 for cnt in report.fleet_computes().values() if cnt > 1
            ) - wasted[mode]
        speedup = walls["fifo"] / max(walls["prefix"], 1e-9)
        print(f"{name}_server_reuse,"
              f"{walls['prefix'] * 1e6 / n_eff:.0f},"
              f"fifo_s={walls['fifo']:.2f};"
              f"prefix_s={walls['prefix']:.2f};"
              f"variants={n_eff};slots={n_conc};"
              f"speedup={speedup:.2f}x;"
              f"shared_recomputed={wasted['prefix']};"
              f"planner_recomputed={deliberate['prefix']}", flush=True)


def bench_eviction() -> None:
    """ISSUE 4: evict-to-admit vs refuse-on-exhausted under a storage
    budget sized to ~50% of the sweep's working set, with the budget
    pre-squatted by stale low-benefit junk (the motivating pathology:
    entries with no recompute-cost metadata and no observed reuse hold
    the budget forever).

    Three runs per workflow: one unconstrained sweep to *measure* the
    working set, then the same grid twice against a junk-filled store at
    half that budget — ``evict_to_admit=False`` (refuse-only baseline:
    nothing can be persisted, in-flight dedupe cannot force-persist
    shared values, so siblings serialize on compute leases and then
    recompute) vs ``True`` (the evictor clears junk, shared prefixes
    persist and are loaded). Reports wall clock, duplicate computes,
    eviction stats, and the ledger-vs-disk drift at drain (must be 0).
    """
    from repro.core import Store, StorageLedger, grid, run_sweep

    n_var = int(os.environ.get("HELIX_BENCH_SWEEP_VARIANTS", "4"))
    sweep_scale = float(os.environ.get("HELIX_BENCH_SWEEP_SCALE", "1"))
    regs = [0.03, 0.3, 0.01, 1.0, 0.1, 3.0]
    n_regs = max(1, (n_var + 1) // 2)
    cases = {
        "census": (W.CensusKnobs(n_rows=max(2000,
                                            int(120_000 * sweep_scale))),
                   W.build_census,
                   {"reg": regs[:n_regs], "eval_threshold": [0.5, 0.7]}),
        "mnist": (W.MNISTKnobs(n_images=max(500,
                                            int(12_000 * sweep_scale)),
                               epochs=max(5, int(60 * sweep_scale))),
                  W.build_mnist,
                  {"reg": [r * 1e-2 for r in regs[:n_regs]],
                   "eval_k": [1, 2]}),
    }
    rng = np.random.default_rng(0)
    for name, (base, build, axes) in cases.items():
        variants = grid(base, axes, build, name=name)[:n_var]
        n_eff = len(variants)
        # 1) measure the working set (unconstrained cold sweep)
        workdir = os.path.join(ROOT, f"{name}_evict_ws")
        shutil.rmtree(workdir, ignore_errors=True)
        ws_report = run_sweep(workdir, variants)
        ws_report.raise_errors()
        ws = max(ws_report.store_bytes, 1)
        budget = max(ws // 2, 1)
        # 2) same grid at 50% budget, store pre-squatted with junk
        chunk = max(512, budget // (8 * 6))   # ≈6 junk entries
        walls, dups, drift = {}, {}, {}
        ev_stats: dict = {}
        for mode in ("refuse", "evict"):
            workdir = os.path.join(ROOT, f"{name}_evict_{mode}")
            shutil.rmtree(workdir, ignore_errors=True)
            store = Store(os.path.join(workdir, "store"))
            junk, i = 0, 0
            while junk < budget:
                junk += store.save(f"junk{i:04d}", "junk",
                                   rng.standard_normal(chunk)).nbytes
                i += 1
            report = run_sweep(workdir, variants,
                               storage_budget_bytes=float(budget),
                               evict_to_admit=(mode == "evict"))
            report.raise_errors()
            walls[mode] = report.wall_seconds
            dups[mode] = sum(c - 1
                             for c in report.fleet_computes().values()
                             if c > 1)
            ev_stats[mode] = report.evictions
            drift[mode] = (StorageLedger(store.ledger_path).used()
                           - store.total_bytes())
        ev = ev_stats["evict"]
        speedup = walls["refuse"] / max(walls["evict"], 1e-9)
        print(f"{name}_eviction,"
              f"{walls['evict'] * 1e6 / n_eff:.0f},"
              f"refuse_s={walls['refuse']:.2f};"
              f"evict_s={walls['evict']:.2f};"
              f"speedup={speedup:.2f}x;variants={n_eff};"
              f"ws_kb={ws / 1024:.0f};budget_kb={budget / 1024:.0f};"
              f"dup_refuse={dups['refuse']};dup_evict={dups['evict']};"
              f"evicted={ev.get('n_evicted', 0)};"
              f"vetoed_live={ev.get('n_vetoed_live', 0)};"
              f"ledger_drift_b={drift['evict']:.0f};"
              f"ledger_drift_refuse_b={drift['refuse']:.0f}", flush=True)


def bench_remote_reuse() -> None:
    """ISSUE 5: cold-host speedup from warm-remote reuse.

    Three phases on the census grid:

    1. **Warm** — a 2-host sweep (separate per-host workdirs, one shared
       remote tier) warms the tier. This phase also proves the cross-host
       protocol: ``fleet_dup`` counts shared signatures blindly computed
       more than once *across hosts* (coordination failures — must be 0;
       deliberate recompute-cheaper-than-load planner choices excluded,
       see ``SweepReport.wasted_recomputes``).
    2. **Cold host, warm remote** — a fresh workdir (nothing local) runs
       the same grid against the warm tier: every reusable prefix is a
       remote fetch instead of a compute.
    3. **Cold host, empty remote** — the same fresh-workdir run against
       an empty tier: the true cold baseline at identical concurrency.

    Headline = phase-3 wall / phase-2 wall (acceptance: ≥ 1.5x).
    ``evict_leased`` is a live probe, not a constant: after the warm
    phase the bench pins a warm entry and attempts a remote eviction of
    it — the count of successful deletes-under-pin is the reported
    number (0 = the lease veto held; ``delete_entry`` must refuse).
    ``evict_vetoed`` is the tier's veto counter over the whole run.
    """
    from repro.core import FsObjectStore, RemoteStore, grid, run_sweep

    n_var = int(os.environ.get("HELIX_BENCH_SWEEP_VARIANTS", "4"))
    sweep_scale = float(os.environ.get("HELIX_BENCH_SWEEP_SCALE", "1"))
    regs = [0.03, 0.3, 0.01, 1.0, 0.1, 3.0]
    n_regs = max(1, (n_var + 1) // 2)
    base = W.CensusKnobs(n_rows=max(2000, int(120_000 * sweep_scale)))
    axes = {"reg": regs[:n_regs], "eval_threshold": [0.5, 0.7]}
    variants = grid(base, axes, W.build_census, name="census")[:n_var]
    n_eff = len(variants)

    # 1) warm the tier from a 2-host fleet (also the dedupe proof)
    remote_root = os.path.join(ROOT, "census_remote_tier")
    shutil.rmtree(remote_root, ignore_errors=True)
    warm_wd = os.path.join(ROOT, "census_remote_warm")
    shutil.rmtree(warm_wd, ignore_errors=True)
    warm = run_sweep(warm_wd, variants, n_hosts=2, remote=remote_root)
    warm.raise_errors()
    fleet_dup = warm.wasted_recomputes()

    # Live probe of the lease-veto invariant: pin a warm entry from a
    # "second host" handle, then try to evict it — the reported number
    # counts successful deletes-under-pin (must stay 0).
    prober = RemoteStore(FsObjectStore(remote_root))
    warm_sigs = sorted(prober.entries())
    evict_leased = 0
    if warm_sigs:
        probe_sig = warm_sigs[0]
        pin = prober.acquire_pin(probe_sig)
        evictor_handle = RemoteStore(FsObjectStore(remote_root))
        if evictor_handle.delete_entry(probe_sig) > 0:
            evict_leased += 1
        evictor_handle.close()
        if pin is not None:
            pin.release()
    prober.close()

    # 2) cold host, warm remote vs 3) cold host, empty remote
    walls = {}
    stats = {}
    for mode, tier in (("warm", remote_root),
                       ("empty", os.path.join(ROOT,
                                              "census_remote_empty"))):
        if mode == "empty":
            shutil.rmtree(tier, ignore_errors=True)
        workdir = os.path.join(ROOT, f"census_remote_cold_{mode}")
        shutil.rmtree(workdir, ignore_errors=True)
        report = run_sweep(workdir, variants, remote=tier)
        report.raise_errors()
        walls[mode] = report.wall_seconds
        stats[mode] = report.remote
    speedup = walls["empty"] / max(walls["warm"], 1e-9)
    veto = stats["warm"].get("n_veto_protected", 0)
    print(f"census_remote_reuse,"
          f"{walls['warm'] * 1e6 / n_eff:.0f},"
          f"cold_s={walls['empty']:.2f};warm_s={walls['warm']:.2f};"
          f"variants={n_eff};speedup={speedup:.2f}x;"
          f"fleet_dup={fleet_dup};"
          f"remote_fetches={stats['warm'].get('n_fetches', 0)};"
          f"evict_leased={evict_leased};evict_vetoed={veto}", flush=True)


def bench_search_reuse() -> None:
    """ISSUE 7: reuse-aware search vs fixed-batch FIFO, equal arm count.

    Phase 1 — **frontier ordering**. The candidate grid is learner-reg ×
    PPR-threshold, enumerated reg-fastest, so consecutive candidates
    *differ* in the expensive knob: a fixed batch of the first K arms
    (``run_sweep``, fifo schedule — the pre-ISSUE-7 workflow of a user
    hand-picking K arms in grid order) trains K distinct models. The
    SearchDriver gets the same budget of K arms over the *whole* grid
    and orders its frontier by the server's marginal-cost estimates:
    after each arm it re-prices the remaining candidates against the
    live store, stays signature-adjacent (same reg, different
    threshold), and trains ~K/2 models. At equal arm count the tuner
    must perform measurably less distinct work: fewer unique signatures
    computed (``saved_sigs`` > 0 — the content-addressed measure; raw
    node-compute counts also reported, but at smoke scale they include
    the planner's deliberate recompute-cheaper-than-load choices on
    tiny extractors) and fewer models trained, with zero wasted
    recomputes.

    Phase 2 — **successive halving**. Four regs race over
    ``train_iters`` levels [iters/5, iters] at eta=2 in eager (ASHA)
    mode: the first two finishers of rung 0 promote and the stragglers
    are cancelled mid-run through the server's cooperative-cancel path.
    The row reports ``ledger_drift_b`` (shared ledger minus on-disk
    bytes after the run — must be 0: early-stopped arms released every
    reservation) and ``wasted`` (blind duplicate computes — must be 0).
    """
    from repro.core import StorageLedger, SweepVariant, run_sweep
    from repro.core.config import EngineConfig
    from repro.core.search import (HalvingConfig, SearchConfig,
                                   SearchDriver)
    from repro.serve import SessionServer

    n_var = int(os.environ.get("HELIX_BENCH_SWEEP_VARIANTS", "4"))
    sweep_scale = float(os.environ.get("HELIX_BENCH_SWEEP_SCALE", "1"))
    regs = [0.03, 0.3, 0.01, 1.0, 0.1, 3.0]
    iters = max(30, int(300 * sweep_scale))
    base = W.CensusKnobs(n_rows=max(2000, int(120_000 * sweep_scale)),
                         train_iters=iters)
    budget = max(2, n_var)
    n_regs = min(len(regs), budget)
    # reg varies fastest: FIFO's first `budget` arms are reg-diverse
    # (each trains its own model); the grid's threshold axis is where
    # the reuse frontier finds signature-adjacent siblings.
    space = [{"reg": r, "eval_threshold": t}
             for t in (0.5, 0.7) for r in regs[:n_regs]]

    def factory(**params):
        return W.build_census(dataclasses.replace(base, **params))

    # 1a) fixed-batch FIFO baseline: the first `budget` arms in grid order
    workdir = os.path.join(ROOT, "census_search_fixed")
    shutil.rmtree(workdir, ignore_errors=True)
    fixed_variants = [
        SweepVariant(name=f"fix{i}", build=(lambda p=p: factory(**p)),
                     knobs=p)
        for i, p in enumerate(space[:budget])]
    fixed = run_sweep(workdir, fixed_variants,
                      engine=EngineConfig(schedule="fifo"),
                      storage=None)
    fixed.raise_errors()
    fixed_nodes = sum(
        r.report.execution.n_computed - len(r.report.execution.deduped)
        for r in fixed.results)
    fixed_sigs = len(fixed.fleet_computes())
    fixed_models = len({v.knobs["reg"] for v in fixed_variants})

    # 1b) the tuner: same budget, whole grid, marginal-cost frontier
    workdir = os.path.join(ROOT, "census_search_tuner")
    shutil.rmtree(workdir, ignore_errors=True)
    server = SessionServer(workdir, registry={"census": factory},
                           engine=EngineConfig(n_sessions=1),
                           poll_interval=0.01)
    try:
        # max_inflight=2 over a 1-slot server: execution stays
        # sequential, but the next pick is submitted while the current
        # arm runs — its shared signatures enter the live multiplicity
        # map, so the leader force-persists them (lease-following) even
        # where cost economics alone would not materialize.
        driver = SearchDriver(
            server, "census", space=space,
            config=SearchConfig(strategy="grid", max_arms=budget,
                                frontier="reuse", max_inflight=2))
        tuned = driver.run()
    finally:
        server.shutdown()
    tuner_nodes = tuned.total_node_computes()
    tuner_sigs = len(tuned.fleet_computes())
    tuner_models = len({a.params["reg"] for a in tuned.arms
                        if a.status != "skipped"})
    print(f"census_search_reuse,"
          f"{tuned.wall_seconds * 1e6 / budget:.0f},"
          f"fixed_sigs={fixed_sigs};tuner_sigs={tuner_sigs};"
          f"saved_sigs={fixed_sigs - tuner_sigs};"
          f"fixed_models={fixed_models};tuner_models={tuner_models};"
          f"fixed_nodes={fixed_nodes};tuner_nodes={tuner_nodes};"
          f"fixed_s={fixed.wall_seconds:.2f};"
          f"tuner_s={tuned.wall_seconds:.2f};"
          f"arms={budget};grid={len(space)};"
          f"wasted={tuned.wasted_recomputes()}", flush=True)

    # 2) eager successive halving over train_iters
    workdir = os.path.join(ROOT, "census_search_halving")
    shutil.rmtree(workdir, ignore_errors=True)
    server = SessionServer(workdir, registry={"census": factory},
                           engine=EngineConfig(n_sessions=2),
                           poll_interval=0.01)
    try:
        driver = SearchDriver(
            server, "census",
            space=[{"reg": r} for r in regs[:4]],
            config=SearchConfig(
                strategy="grid", metric="checkResults.value",
                max_inflight=2,
                halving=HalvingConfig(resource="train_iters",
                                      levels=[max(10, iters // 5), iters],
                                      eta=2.0, eager=True)))
        halved = driver.run()
        drift = (StorageLedger(server.store.ledger_path).used()
                 - server.store.total_bytes())
    finally:
        server.shutdown()
    best = halved.best()
    print(f"census_search_halving,"
          f"{halved.wall_seconds * 1e6 / max(len(halved.arms), 1):.0f},"
          f"rungs={len(halved.rungs)};arms={len(halved.arms)};"
          f"cancelled={halved.n_cancelled()};"
          f"skipped={sum(1 for a in halved.arms if a.status == 'skipped')};"
          f"best_reg={best.base_params['reg'] if best else 'na'};"
          f"best_metric={best.metric if best else 'na'};"
          f"ledger_drift_b={drift:.0f};"
          f"wasted={halved.wasted_recomputes()}", flush=True)


def bench_incremental() -> None:
    """ISSUE 8: daily-retrain on an append-mostly source — chunk-spliced
    delta iteration vs. a cold full retrain of the same grown table.

    Warm a store with an ``n_chunks``-chunk census table, append 10 %
    (one chunk), retrain in the warm workdir (delta: map/assoc_reduce
    nodes splice cached chunks, only the appended chunk runs) and in a
    cold workdir (full recompute). Asserts the delta retrain lands under
    0.5× the cold wall-clock and the outputs are bit-identical; writes
    ``results/bench/incremental.csv``.

    Env knobs: HELIX_BENCH_INC_CHUNKS (default 10),
    HELIX_BENCH_INC_ROWS (rows per chunk, default 8000 — CI smoke
    passes something small)."""
    n_chunks = int(os.environ.get("HELIX_BENCH_INC_CHUNKS", "10"))
    rows = int(os.environ.get("HELIX_BENCH_INC_ROWS", "8000"))
    k0 = W.IncrementalCensusKnobs(n_chunks=n_chunks, rows_per_chunk=rows)
    k1 = dataclasses.replace(k0, n_chunks=n_chunks + 1)   # +10 % append

    def timed_run(workdir, knobs, reuse=False):
        if not reuse:
            shutil.rmtree(workdir, ignore_errors=True)
        sess = IterativeSession(workdir, policy=Policy.ALWAYS,
                                storage_budget_bytes=BUDGET)
        t0 = time.perf_counter()
        rep = sess.run(W.build_census_incremental(knobs))
        return time.perf_counter() - t0, rep

    warm_dir = os.path.join(ROOT, "incremental_warm")
    warm_s, _ = timed_run(warm_dir, k0)
    delta_s, delta_rep = timed_run(warm_dir, k1, reuse=True)
    cold_s, cold_rep = timed_run(os.path.join(ROOT, "incremental_cold"),
                                 k1)
    assert delta_rep.outputs["dailyEval"] == cold_rep.outputs["dailyEval"], \
        "delta retrain diverged from cold recompute"
    spliced = sum(delta_rep.execution.chunk_reused.values())
    recomputed = sum(delta_rep.execution.chunk_computed.values())
    ratio = delta_s / max(cold_s, 1e-9)
    os.makedirs(ROOT, exist_ok=True)
    with open(os.path.join(ROOT, "incremental.csv"), "w") as f:
        f.write("scenario,n_chunks,rows_per_chunk,seconds,"
                "chunks_reused,chunks_recomputed\n")
        f.write(f"warm,{n_chunks},{rows},{warm_s:.3f},0,{3 * n_chunks}\n")
        f.write(f"delta,{n_chunks + 1},{rows},{delta_s:.3f},"
                f"{spliced},{recomputed}\n")
        f.write(f"cold,{n_chunks + 1},{rows},{cold_s:.3f},0,"
                f"{3 * (n_chunks + 1)}\n")
    print(f"incremental_daily_retrain,{delta_s * 1e6:.0f},"
          f"delta_s={delta_s:.2f};cold_s={cold_s:.2f};"
          f"ratio={ratio:.2f};spliced={spliced};recomputed={recomputed}",
          flush=True)
    assert ratio < 0.5, (
        f"delta retrain {delta_s:.2f}s not under 0.5x cold {cold_s:.2f}s")


def bench_tier() -> None:
    """ISSUE 9: memory-tier acceptance on the LM training workflow.

    One session, one store, two runs of the identical LM workflow:

    1. **Cold** — trains the small transformer and materializes every
       node (Policy.ALWAYS); the store's write-through memory tier
       admits each durable value on the way to disk.
    2. **Warm (same process)** — reruns the same workflow: every reuse
       is a signature hit that the memory tier must serve zero-copy.

    Asserted, not just reported: the warm run is bit-identical to the
    cold run; ≥90 % of its reused bytes come from the memory tier; the
    warm run's hit path reads **zero** ``.npy`` leaf files; a timed
    memory hit on the largest signature beats a fresh-process disk
    reload of the same signature by ≥5x; and after both runs each
    tier's ledger equals the bytes it actually holds (shared ledger ==
    disk, memory accounting == a recount of resident entries).
    """
    from repro.core import Store, StorageLedger
    from repro.core.config import StoreConfig

    steps = int(os.environ.get("HELIX_BENCH_LM_STEPS", "4"))
    d_model = int(os.environ.get("HELIX_BENCH_LM_DM", "128"))
    k = dataclasses.replace(W.LMKnobs(), steps=steps, d_model=d_model)

    workdir = os.path.join(ROOT, "lm_tier")
    shutil.rmtree(workdir, ignore_errors=True)
    sess = IterativeSession(
        workdir, policy=Policy.ALWAYS,
        storage=StoreConfig(budget_bytes=float(BUDGET),
                            shared_budget=True,   # arms the ledger check
                            mem_budget_bytes=256e6))
    store = sess.store

    t0 = time.perf_counter()
    rep_cold = sess.run(W.build_lm(k))
    cold_s = time.perf_counter() - t0

    # Snapshot the counters the warm run must (not) move.
    def stats_snap():
        return {t: dict(s) for t, s in store.load_stats.items()}

    before = stats_snap()
    npy_before = store.npy_leaf_reads
    t0 = time.perf_counter()
    rep_warm = sess.run(W.build_lm(k))
    warm_s = time.perf_counter() - t0
    after = stats_snap()
    npy_delta = store.npy_leaf_reads - npy_before

    assert rep_warm.outputs["evalLoss"] == rep_cold.outputs["evalLoss"], \
        "warm memory-served rerun diverged from the cold run"

    mem_bytes = after["memory"]["bytes"] - before["memory"]["bytes"]
    disk_bytes = after["local"]["bytes"] - before["local"]["bytes"]
    reused = mem_bytes + disk_bytes
    mem_frac = mem_bytes / max(reused, 1)
    assert reused > 0, "warm rerun reused nothing — no signature hits"
    assert mem_frac >= 0.9, (
        f"memory tier served only {mem_frac:.0%} of reused bytes "
        f"({mem_bytes}B mem vs {disk_bytes}B disk)")
    assert npy_delta == 0, (
        f"warm hit path read {npy_delta} .npy leaf files (must be 0)")

    # Timed hit-vs-reload on the largest materialization (the TrainState).
    store.writer_drain()
    big_sig = max(store.entries().items(),
                  key=lambda kv: kv[1].get("nbytes", 0))[0]
    mem_us = min(_timed_load(store, big_sig) for _ in range(5))
    cold_store = Store(store.root, mem_budget_bytes=0.0)
    disk_us = min(_timed_load(cold_store, big_sig) for _ in range(5))
    ratio = disk_us / max(mem_us, 1e-9)
    assert ratio >= 5.0, (
        f"memory hit ({mem_us:.0f}us) only {ratio:.1f}x faster than disk "
        f"reload ({disk_us:.0f}us); need >=5x")

    # Per-tier ledger == bytes held.
    ledger_drift = StorageLedger(store.ledger_path).used() \
        - store.total_bytes()
    tiers = store.tier_status()
    mem_drift = tiers["memory"]["bytes"] - store._mem.recount()
    assert ledger_drift == 0, f"shared ledger drift: {ledger_drift}B"
    assert mem_drift == 0, f"memory-tier accounting drift: {mem_drift}B"

    print(f"lm_tier_warm,{warm_s * 1e6:.0f},"
          f"cold_s={cold_s:.2f};warm_s={warm_s:.2f};"
          f"mem_frac={mem_frac:.2f};npy_reads={npy_delta};"
          f"mem_hit_us={mem_us:.0f};disk_load_us={disk_us:.0f};"
          f"hit_speedup={ratio:.1f}x;"
          f"mem_hits={after['memory']['hits'] - before['memory']['hits']};"
          f"ledger_drift_b={ledger_drift};mem_drift_b={mem_drift}",
          flush=True)


def _timed_load(store, sig: str) -> float:
    t0 = time.perf_counter()
    store.load(sig)
    return (time.perf_counter() - t0) * 1e6


def bench_engine_overlap() -> None:
    """Scheduler-overlap ceiling: a wide diamond of GIL-releasing 150 ms
    wait stubs (no CPU contention). Near-width× speedup means the ready-set
    engine adds no serialization beyond the DAG itself — any gap between
    this and bench_parallel_speedup is hardware contention (shared SMT
    ports / memory bandwidth), not engine overhead."""
    import tempfile

    from repro.core.dag import DAG, Node, State
    from repro.core.executor import execute
    from repro.core.omp import Materializer
    from repro.core.store import Store

    width = 8
    secs = {}
    for workers in (1, width):
        nodes = [Node("src", lambda: 0.0)]
        for i in range(width):
            nodes.append(Node(f"b{i}", lambda x: (time.sleep(0.15), x)[1],
                              parents=("src",)))
        nodes.append(Node("join", lambda *vs: sum(vs),
                          parents=tuple(f"b{i}" for i in range(width)),
                          is_output=True))
        dag = DAG(nodes)
        states = {n: State.COMPUTE for n in dag.nodes}
        with tempfile.TemporaryDirectory() as td:
            t0 = time.perf_counter()
            execute(dag, {n: f"sig-{n}" for n in dag.nodes}, states,
                    Store(td), Materializer(policy=Policy.NEVER),
                    max_workers=workers)
            secs[workers] = time.perf_counter() - t0
    print(f"engine_overlap_w{width},{secs[width] * 1e6:.0f},"
          f"seq_s={secs[1]:.2f};par_s={secs[width]:.2f};"
          f"speedup={secs[1] / max(secs[width], 1e-9):.2f}x", flush=True)


def bench_multitenant() -> None:
    """ISSUE 10: consistent-hash routing vs random placement, 2 shards.

    A fleet of two session servers (fair schedule, tenancy on) serves
    N workflow families through a :class:`~repro.serve.FleetRouter`.
    After a warm-up pass places every family's prefix on its rendezvous
    home shard, the same submissions rerun twice against the warm fleet:

    * ``route="hash"`` (the default): every repeat lands on the shard
      already holding its prefix — **zero** prefix recomputes, asserted
      structurally (a fresh router instance is used, proving placement
      is state-free);
    * ``route="random"`` (seeded, the control): placement by coin flip
      sends a fraction of the families to the cold shard, which — with
      no shared remote tier — must recompute their prefixes from
      scratch.

    The row reports both wall clocks and the recompute counts; the
    acceptance bar is hash ≥ 1.3x over random on the warm rerun. Also
    checks each shard's budget ledger still equals its on-disk bytes
    after all three passes (tenancy's scoped reservations reconcile).
    """
    import threading

    from repro.core import StorageLedger
    from repro.core.config import EngineConfig
    from repro.core.workflow import Workflow
    from repro.serve import FleetRouter, SessionServer, TenantSpec

    scale = float(os.environ.get("HELIX_BENCH_SWEEP_SCALE", "1"))
    n_fam = int(os.environ.get("HELIX_BENCH_TENANT_FAMILIES", "6"))
    work = max(40, int(150 * scale))
    dim = 128

    lock = threading.Lock()
    feat_calls: dict[str, int] = {}

    def build(family="f0", reg=0.1):
        wf = Workflow(f"{family}-{reg}")
        src = wf.source(
            "src",
            lambda d=dim: np.arange(d * d, dtype=np.float64).reshape(d, d),
            config=("v1", family))

        def featurize(m, fam=family):
            with lock:
                feat_calls[fam] = feat_calls.get(fam, 0) + 1
            acc = m.copy()
            for _ in range(work):
                acc = np.tanh(acc @ m.T @ m / m.size)
            return acc

        feat = wf.extractor("feat", featurize, [src],
                            config=("feat", family))
        model = wf.learner("model",
                           lambda z, r=reg: float(np.sum(z * z)) * r,
                           [feat], config=("LR", reg))
        out = wf.reducer("eval", lambda m: {"score": m}, [model],
                         config=("eval",))
        wf.output(out)
        return wf

    registry = {"fam": build}
    servers = {}
    for sid in ("s0", "s1"):
        workdir = os.path.join(ROOT, f"multitenant_{sid}")
        shutil.rmtree(workdir, ignore_errors=True)
        servers[sid] = SessionServer(
            workdir, registry=registry,
            tenants={"*": TenantSpec(weight=1.0)},
            engine=EngineConfig(schedule="fair", n_sessions=2),
            poll_interval=0.01)
    arms = [(f"f{i}", 0.1) for i in range(n_fam)]

    def run_all(router):
        jobs = [router.submit("fam", {"family": f, "reg": r})
                for f, r in arms]
        for j in jobs:
            out = router.wait(j, timeout=600.0)
            assert out["status"] == "done", out

    def total_feats():
        with lock:
            return sum(feat_calls.values())

    try:
        run_all(FleetRouter(servers, registry=registry, tenant="warm"))
        warmed = total_feats()
        assert warmed == n_fam, "warm pass must compute each family once"

        t0 = time.perf_counter()
        run_all(FleetRouter(servers, registry=registry, tenant="rerun"))
        hash_s = time.perf_counter() - t0
        hash_recomputed = total_feats() - warmed
        assert hash_recomputed == 0, \
            "hash routing recomputed a cached prefix on a warm fleet"

        seed = int(os.environ.get("HELIX_CHAOS_SEED", "1234"))
        t0 = time.perf_counter()
        run_all(FleetRouter(servers, registry=registry, tenant="rerun",
                            route="random", seed=seed))
        random_s = time.perf_counter() - t0
        random_recomputed = total_feats() - warmed - hash_recomputed

        drift = max(abs(StorageLedger(s.store.ledger_path).used()
                        - s.store.total_bytes())
                    for s in servers.values())
    finally:
        for s in servers.values():
            s.shutdown()

    speedup = random_s / max(hash_s, 1e-9)
    print(f"multitenant_routing,"
          f"{hash_s * 1e6 / len(arms):.0f},"
          f"hash_s={hash_s:.3f};random_s={random_s:.3f};"
          f"speedup={speedup:.2f}x;"
          f"families={n_fam};shards=2;seed={seed};"
          f"hash_recomputed={hash_recomputed};"
          f"random_recomputed={random_recomputed};"
          f"ledger_drift_b={drift:.0f}", flush=True)


def main() -> None:
    bench_cumulative_runtime()
    bench_storage()
    bench_state_fractions()
    bench_optimizer_overhead()
    bench_parallel_speedup()
    bench_sweep_reuse()
    bench_server_reuse()
    bench_eviction()
    bench_remote_reuse()
    bench_search_reuse()
    bench_incremental()
    bench_tier()
    bench_engine_overlap()
    bench_multitenant()


if __name__ == "__main__":
    if len(sys.argv) > 1:     # run the named benches only
        for bench_name in sys.argv[1:]:
            fn = globals().get(bench_name)
            if not (bench_name.startswith("bench_") and callable(fn)):
                avail = sorted(n for n, v in list(globals().items())
                               if n.startswith("bench_") and callable(v))
                sys.exit(f"unknown benchmark {bench_name!r}; available: "
                         + ", ".join(avail))
            fn()
    else:
        main()
