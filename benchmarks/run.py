"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  bench_cumulative_runtime  — paper Fig. 5 / Fig. 9(a,b,e,f): cumulative
      runtime over 10 iterations for each workflow under OPT / AM / NM
      (NM ≈ KeystoneML's materialize-nothing; AM ≈ DeepDive's
      materialize-everything).
  bench_storage             — paper Fig. 9(c,d): store size after the runs.
  bench_state_fractions     — paper Fig. 8: prune/load/compute fractions,
      OPT vs AM (OPT should match AM's reuse without AM's storage).
  bench_optimizer_overhead  — OEP max-flow solve time vs DAG size (the
      optimizer must be negligible next to operator runtimes).
  bench_parallel_speedup    — sequential engine (max_workers=1, the paper's
      §5.3 discipline) vs the pipelined ready-set engine (worker pool +
      LOAD prefetch + async writer queue) on workflows with branch
      parallelism, reported next to the Fig. 5 numbers.

Env knobs: HELIX_BENCH_ITERS (default 10), HELIX_BENCH_WORKFLOWS (csv list),
HELIX_BENCH_PAR_WORKERS (worker-pool width for the pipelined engine).
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import sys
import time

# Pin BLAS to one thread *before* numpy loads: the speedup benchmark
# measures engine-level branch parallelism, which double-counts if BLAS
# also fans out every matmul internally. Applies equally to both engines.
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.dirname(__file__))

from repro.core import IterativeSession, Policy  # noqa: E402
from repro.core.dag import DAG, Node             # noqa: E402
from repro.core import oep                       # noqa: E402

import workflows as W                            # noqa: E402

N_ITERS = int(os.environ.get("HELIX_BENCH_ITERS", "10"))
SELECT = os.environ.get("HELIX_BENCH_WORKFLOWS", "census,genomics,nlp,mnist"
                        ).split(",")
BUDGET = 10 * 1024 ** 3    # paper §6.3: 10 GB storage budget
ROOT = os.path.join(os.path.dirname(__file__), os.pardir,
                    "results", "bench")


def _run_policy(wd: W.WorkflowDef, policy: Policy, seed: int = 0):
    """Run N_ITERS iterations; returns (per-iter seconds, reports)."""
    workdir = os.path.join(ROOT, f"{wd.name}_{policy.value}")
    shutil.rmtree(workdir, ignore_errors=True)
    sess = IterativeSession(workdir, policy=policy,
                            storage_budget_bytes=BUDGET)
    knobs = W.iteration_schedule(wd, N_ITERS, seed)
    times, reports = [], []
    for kn in knobs:
        wf = wd.build(kn)
        t0 = time.perf_counter()
        rep = sess.run(wf)
        times.append(time.perf_counter() - t0)
        reports.append(rep)
    return times, reports


_CACHE: dict = {}


def _results(wd: W.WorkflowDef, policy: Policy):
    key = (wd.name, policy)
    if key not in _CACHE:
        _CACHE[key] = _run_policy(wd, policy)
    return _CACHE[key]


def bench_cumulative_runtime() -> None:
    """Fig. 5 / 9: cumulative runtime per workflow per policy."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        cum = {}
        for policy in (Policy.NEVER, Policy.ALWAYS, Policy.OPT):
            times, _ = _results(wd, policy)
            cum[policy] = sum(times)
        for policy, total in cum.items():
            speedup = cum[Policy.NEVER] / max(total, 1e-9)
            print(f"{name}_{policy.value}_cumulative,"
                  f"{total * 1e6 / N_ITERS:.0f},"
                  f"total_s={total:.2f};speedup_vs_nm={speedup:.2f}x",
                  flush=True)


def bench_storage() -> None:
    """Fig. 9(c,d): storage snapshots."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        for policy in (Policy.ALWAYS, Policy.OPT):
            _, reports = _results(wd, policy)
            final = reports[-1].store_bytes
            peak = max(r.store_bytes for r in reports)
            print(f"{name}_{policy.value}_storage,"
                  f"{final / 1024:.0f},"
                  f"peak_kb={peak / 1024:.0f}", flush=True)


def bench_state_fractions() -> None:
    """Fig. 8: aggregate state distribution across reuse iterations."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        for policy in (Policy.OPT, Policy.ALWAYS):
            _, reports = _results(wd, policy)
            comp = sum(r.execution.n_computed for r in reports[1:])
            load = sum(r.execution.n_loaded for r in reports[1:])
            prune = sum(r.execution.n_pruned for r in reports[1:])
            tot = max(comp + load + prune, 1)
            print(f"{name}_{policy.value}_states,"
                  f"{comp},"
                  f"compute={comp / tot:.2f};load={load / tot:.2f};"
                  f"prune={prune / tot:.2f}", flush=True)


def bench_optimizer_overhead() -> None:
    """OEP (max-flow) solve time vs DAG size."""
    rng = np.random.default_rng(0)
    for n in (50, 200, 1000):
        nodes = []
        for i in range(n):
            k = int(min(i, 3))
            parents = tuple(f"n{j}" for j in
                            rng.choice(i, k, replace=False)) if i else ()
            nodes.append(Node(name=f"n{i}", fn=None, parents=parents,
                              is_output=(i == n - 1)))
        dag = DAG(nodes)
        cc = {f"n{i}": float(rng.uniform(0.1, 10)) for i in range(n)}
        lc = {f"n{i}": (float(rng.uniform(0.1, 5))
                        if rng.random() < 0.7 else None) for i in range(n)}
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            oep.plan(dag, cc, lc, original=set())
        dt = (time.perf_counter() - t0) / reps
        print(f"oep_solver_n{n},{dt * 1e6:.0f},nodes={n}", flush=True)


def bench_parallel_speedup() -> None:
    """Sequential vs pipelined engine, wall clock of execute().

    census exercises the paper's Fig. 3 parallel feature extractors;
    mnist runs with 12 independent random-FFT towers (KeystoneML-style
    block featurization + per-tower heads). Each engine runs the same
    3-iteration schedule (cold start + two edits) on a fresh store.
    """
    n_workers = int(os.environ.get("HELIX_BENCH_PAR_WORKERS",
                                   str(max(2, os.cpu_count() or 2))))
    n_iters = 3
    cases = {
        "census": (W.WORKFLOWS["census"], {}),
        # Tower ensemble (KeystoneML block solve): 12 independent
        # fft→head→logits branches. PPR-only edits keep the tower shape
        # stable across the schedule (towers are nondeterministic, so every
        # iteration re-runs the full fan-out — the branch-parallel hot
        # path this benchmark isolates). NOTE: attainable speedup is capped
        # by the host — on SMT-sibling vCPU pairs, FP-SIMD numpy work
        # scales at best ~1.4x even fully parallel; on >=4 distinct cores
        # the tower fan-out exceeds 1.5-2x.
        "mnist": (W.WORKFLOWS["mnist"],
                  dict(knobs0=dataclasses.replace(
                           W.MNISTKnobs(), n_towers=12, n_features=6144,
                           n_images=8000, epochs=4),
                       freqs={"PPR": 1.0})),
    }
    for name, (wd, overrides) in cases.items():
        if overrides:
            wd = dataclasses.replace(wd, **overrides)
        engine_secs = {}
        for mode, workers in (("seq", 1), ("par", n_workers)):
            workdir = os.path.join(ROOT, f"{name}_speedup_{mode}")
            shutil.rmtree(workdir, ignore_errors=True)
            sess = IterativeSession(
                workdir, policy=Policy.OPT, storage_budget_bytes=BUDGET,
                max_workers=workers, prefetch_depth=8,
                async_materialization=(workers > 1))
            secs = 0.0
            for kn in W.iteration_schedule(wd, n_iters, seed=0):
                rep = sess.run(wd.build(kn))
                secs += rep.execution.total_seconds
            engine_secs[mode] = secs
        speedup = engine_secs["seq"] / max(engine_secs["par"], 1e-9)
        print(f"{name}_parallel_speedup,"
              f"{engine_secs['par'] * 1e6 / n_iters:.0f},"
              f"seq_s={engine_secs['seq']:.2f};par_s={engine_secs['par']:.2f};"
              f"workers={n_workers};speedup={speedup:.2f}x", flush=True)


def bench_engine_overlap() -> None:
    """Scheduler-overlap ceiling: a wide diamond of GIL-releasing 150 ms
    wait stubs (no CPU contention). Near-width× speedup means the ready-set
    engine adds no serialization beyond the DAG itself — any gap between
    this and bench_parallel_speedup is hardware contention (shared SMT
    ports / memory bandwidth), not engine overhead."""
    import tempfile

    from repro.core.dag import DAG, Node, State
    from repro.core.executor import execute
    from repro.core.omp import Materializer
    from repro.core.store import Store

    width = 8
    secs = {}
    for workers in (1, width):
        nodes = [Node("src", lambda: 0.0)]
        for i in range(width):
            nodes.append(Node(f"b{i}", lambda x: (time.sleep(0.15), x)[1],
                              parents=("src",)))
        nodes.append(Node("join", lambda *vs: sum(vs),
                          parents=tuple(f"b{i}" for i in range(width)),
                          is_output=True))
        dag = DAG(nodes)
        states = {n: State.COMPUTE for n in dag.nodes}
        with tempfile.TemporaryDirectory() as td:
            t0 = time.perf_counter()
            execute(dag, {n: f"sig-{n}" for n in dag.nodes}, states,
                    Store(td), Materializer(policy=Policy.NEVER),
                    max_workers=workers)
            secs[workers] = time.perf_counter() - t0
    print(f"engine_overlap_w{width},{secs[width] * 1e6:.0f},"
          f"seq_s={secs[1]:.2f};par_s={secs[width]:.2f};"
          f"speedup={secs[1] / max(secs[width], 1e-9):.2f}x", flush=True)


def main() -> None:
    bench_cumulative_runtime()
    bench_storage()
    bench_state_fractions()
    bench_optimizer_overhead()
    bench_parallel_speedup()
    bench_engine_overlap()


if __name__ == "__main__":
    if len(sys.argv) > 1:     # run the named benches only
        for bench_name in sys.argv[1:]:
            fn = globals().get(bench_name)
            if not (bench_name.startswith("bench_") and callable(fn)):
                avail = sorted(n for n, v in list(globals().items())
                               if n.startswith("bench_") and callable(v))
                sys.exit(f"unknown benchmark {bench_name!r}; available: "
                         + ", ".join(avail))
            fn()
    else:
        main()
