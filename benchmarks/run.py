"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  bench_cumulative_runtime  — paper Fig. 5 / Fig. 9(a,b,e,f): cumulative
      runtime over 10 iterations for each workflow under OPT / AM / NM
      (NM ≈ KeystoneML's materialize-nothing; AM ≈ DeepDive's
      materialize-everything).
  bench_storage             — paper Fig. 9(c,d): store size after the runs.
  bench_state_fractions     — paper Fig. 8: prune/load/compute fractions,
      OPT vs AM (OPT should match AM's reuse without AM's storage).
  bench_optimizer_overhead  — OEP max-flow solve time vs DAG size (the
      optimizer must be negligible next to operator runtimes).

Env knobs: HELIX_BENCH_ITERS (default 10), HELIX_BENCH_WORKFLOWS (csv list).
"""
from __future__ import annotations

import os
import shutil
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.dirname(__file__))

from repro.core import IterativeSession, Policy  # noqa: E402
from repro.core.dag import DAG, Node             # noqa: E402
from repro.core import oep                       # noqa: E402

import workflows as W                            # noqa: E402

N_ITERS = int(os.environ.get("HELIX_BENCH_ITERS", "10"))
SELECT = os.environ.get("HELIX_BENCH_WORKFLOWS", "census,genomics,nlp,mnist"
                        ).split(",")
BUDGET = 10 * 1024 ** 3    # paper §6.3: 10 GB storage budget
ROOT = os.path.join(os.path.dirname(__file__), os.pardir,
                    "results", "bench")


def _run_policy(wd: W.WorkflowDef, policy: Policy, seed: int = 0):
    """Run N_ITERS iterations; returns (per-iter seconds, reports)."""
    workdir = os.path.join(ROOT, f"{wd.name}_{policy.value}")
    shutil.rmtree(workdir, ignore_errors=True)
    sess = IterativeSession(workdir, policy=policy,
                            storage_budget_bytes=BUDGET)
    knobs = W.iteration_schedule(wd, N_ITERS, seed)
    times, reports = [], []
    for kn in knobs:
        wf = wd.build(kn)
        t0 = time.perf_counter()
        rep = sess.run(wf)
        times.append(time.perf_counter() - t0)
        reports.append(rep)
    return times, reports


_CACHE: dict = {}


def _results(wd: W.WorkflowDef, policy: Policy):
    key = (wd.name, policy)
    if key not in _CACHE:
        _CACHE[key] = _run_policy(wd, policy)
    return _CACHE[key]


def bench_cumulative_runtime() -> None:
    """Fig. 5 / 9: cumulative runtime per workflow per policy."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        cum = {}
        for policy in (Policy.NEVER, Policy.ALWAYS, Policy.OPT):
            times, _ = _results(wd, policy)
            cum[policy] = sum(times)
        for policy, total in cum.items():
            speedup = cum[Policy.NEVER] / max(total, 1e-9)
            print(f"{name}_{policy.value}_cumulative,"
                  f"{total * 1e6 / N_ITERS:.0f},"
                  f"total_s={total:.2f};speedup_vs_nm={speedup:.2f}x",
                  flush=True)


def bench_storage() -> None:
    """Fig. 9(c,d): storage snapshots."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        for policy in (Policy.ALWAYS, Policy.OPT):
            _, reports = _results(wd, policy)
            final = reports[-1].store_bytes
            peak = max(r.store_bytes for r in reports)
            print(f"{name}_{policy.value}_storage,"
                  f"{final / 1024:.0f},"
                  f"peak_kb={peak / 1024:.0f}", flush=True)


def bench_state_fractions() -> None:
    """Fig. 8: aggregate state distribution across reuse iterations."""
    for name in SELECT:
        wd = W.WORKFLOWS[name]
        for policy in (Policy.OPT, Policy.ALWAYS):
            _, reports = _results(wd, policy)
            comp = sum(r.execution.n_computed for r in reports[1:])
            load = sum(r.execution.n_loaded for r in reports[1:])
            prune = sum(r.execution.n_pruned for r in reports[1:])
            tot = max(comp + load + prune, 1)
            print(f"{name}_{policy.value}_states,"
                  f"{comp},"
                  f"compute={comp / tot:.2f};load={load / tot:.2f};"
                  f"prune={prune / tot:.2f}", flush=True)


def bench_optimizer_overhead() -> None:
    """OEP (max-flow) solve time vs DAG size."""
    rng = np.random.default_rng(0)
    for n in (50, 200, 1000):
        nodes = []
        for i in range(n):
            k = int(min(i, 3))
            parents = tuple(f"n{j}" for j in
                            rng.choice(i, k, replace=False)) if i else ()
            nodes.append(Node(name=f"n{i}", fn=None, parents=parents,
                              is_output=(i == n - 1)))
        dag = DAG(nodes)
        cc = {f"n{i}": float(rng.uniform(0.1, 10)) for i in range(n)}
        lc = {f"n{i}": (float(rng.uniform(0.1, 5))
                        if rng.random() < 0.7 else None) for i in range(n)}
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            oep.plan(dag, cc, lc, original=set())
        dt = (time.perf_counter() - t0) / reps
        print(f"oep_solver_n{n},{dt * 1e6:.0f},nodes={n}", flush=True)


def main() -> None:
    bench_cumulative_runtime()
    bench_storage()
    bench_state_fractions()
    bench_optimizer_overhead()


if __name__ == "__main__":
    main()
