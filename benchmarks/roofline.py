"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: 50 GB/s

Record sources (two sweeps; see launch/dryrun.py):

  * **probe** records (``--probe``): layer scans UNROLLED and ONE microbatch
    compiled — XLA's cost_analysis counts while-loop bodies once, so scanned
    graphs under-report FLOPs/bytes/collectives by ~layers×accum; the probe
    restores exact counts. Terms here are scaled back up by ``accum_scale``
    (with the optimizer's one-off bytes removed before scaling and re-added:
    ~24 B/param/device = bf16 param r/w + fp32 m,v r/w + fp32 grad read).
  * **deployment** records (scanned, full batch): the graph that actually
    runs — used for the memory-fit column (peak temp + args vs 16 GB HBM).

Terms per (arch × shape) cell, seconds:

    compute    = probe_flops_per_device · accum / peak
    memory     = probe_bytes_per_device(adj) · accum / hbm_bw
    collective = probe_collective_wire_bytes_per_device · accum / link_bw

plus MODEL_FLOPS/HLO_FLOPS (useful-compute ratio) and the roofline fraction
= ideal / dominant, ideal = max(model-FLOPs term, min-arg-bytes term).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
OPT_BYTES_PER_PARAM = 24.0   # bf16 p r/w + f32 m,v r/w + f32 grad read

# --------------------------------------------------------------------------
# Analytic fused-memory model. XLA *CPU* 'bytes accessed' reflects the CPU
# backend's (near-absent) fusion and overstates TPU HBM traffic 10-30×; the
# spec's memory term is still reported (memory_hlo_s), but the bottleneck
# call uses this model of what a fused TPU executable actually moves:
#
#   train    1.5·args  +  C_ACT·L·B_dev·S·d·2B   (residual-stream passes,
#            C_ACT = 12: ~4 fwd + 4 remat + 4 bwd)
#            + 6 passes over attention scores (fp32) when not flash/chunked
#            + MoE dispatch (k·cf blow-up, 3 passes)
#            + SSD intra-chunk decay tensors (3 passes, fp32)
#   prefill  args + 4 passes·L·B_dev·S·d·2B + 2 passes over scores + cache
#   decode   args (params + cache read once) + written cache slots
# --------------------------------------------------------------------------
def _memory_model_bytes(rec: dict, cfg, sh) -> float:
    n_data = 16                        # batch shards on the 16×16 pod
    n_model = 16
    b_dev = max(sh.batch // n_data, 1)
    args = rec.get("arg_bytes_per_device", 0.0)
    d = cfg.d_model
    L = cfg.num_layers if cfg.encdec is None else (
        cfg.encdec.enc_layers + cfg.encdec.dec_layers)
    heads_dev = max(cfg.num_heads // n_model, 1)
    s = sh.seq if cfg.encdec is None else min(sh.seq, 4096)

    def scores(sq, sk, passes):
        if cfg.attn_impl in ("chunked", "flash"):
            return 0.0   # online-softmax: scores never round-trip HBM
        total = 0.0
        for i in range(cfg.num_layers if cfg.encdec is None else 0):
            if not cfg.layer_is_attn(i):
                continue
            w = cfg.layer_window(i)
            eff = min(sk, w) if w else sk
            total += passes * heads_dev * b_dev * sq * eff * 4.0
        if cfg.encdec is not None:
            total += passes * heads_dev * b_dev * sq * sk * 4.0 * L
        return total

    moe = 0.0
    if cfg.moe is not None:
        n_moe = sum(cfg.layer_is_moe(i) for i in range(cfg.num_layers))
        moe = 3.0 * n_moe * cfg.moe.top_k * cfg.moe.capacity_factor \
            * b_dev * s * d * 2.0
    ssd = 0.0
    if cfg.ssm is not None:
        n_ssm = sum(not cfg.layer_is_attn(i) for i in range(cfg.num_layers))
        d_in = cfg.ssm.expand * d
        hh = d_in // cfg.ssm.head_dim
        ssd = 3.0 * n_ssm * b_dev * (s // max(cfg.ssm.chunk, 1) + 1) \
            * cfg.ssm.chunk ** 2 * hh * 4.0

    if sh.kind == "train":
        act = 12.0 * L * b_dev * s * d * 2.0
        return 1.5 * args + act + scores(s, s, 6) + 2 * moe + 2 * ssd
    if sh.kind == "prefill":
        act = 4.0 * L * b_dev * s * d * 2.0
        return args + act + scores(s, s, 2) + moe + ssd
    # decode: params + cache read once; tiny activations
    return args + 4.0 * L * b_dev * d * 2.0


def analyze_record(rec: dict, deploy: dict | None = None) -> dict | None:
    if not rec.get("ok"):
        return None
    ca = rec.get("cost_analysis")
    if not isinstance(ca, dict) or "flops" not in ca:
        return None
    n = rec["n_devices"]
    accum = rec.get("accum_scale", 1) or 1
    flops_dev = ca["flops"] * accum
    bytes_dev = ca.get("bytes accessed", 0.0)
    if accum > 1:
        # optimizer traffic happens once per step, not per microbatch
        opt_bytes = OPT_BYTES_PER_PARAM * rec.get("param_count", 0) / n
        bytes_dev = max(bytes_dev - opt_bytes, 0.0) * accum + opt_bytes
    coll = rec.get("collectives", {})
    wire_dev = sum(coll.get("wire_bytes", {}).values()) * accum
    operand_dev = sum(coll.get("operand_bytes", {}).values()) * accum

    compute_s = flops_dev / PEAK_FLOPS
    memory_hlo_s = bytes_dev / HBM_BW
    try:
        import dataclasses as _dc
        import sys, os as _os
        sys.path.insert(0, _os.path.join(_os.path.dirname(__file__),
                                         _os.pardir, "src"))
        from repro import configs as _configs
        from repro.launch import shapes as _shapes
        cfg = _configs.get(rec["arch"])
        ov = {k: v for k, v in (rec.get("overrides") or {}).items()
              if k not in ("unroll", "grad_accum")}
        if ov:
            cfg = _dc.replace(cfg, **ov)
        sh = _shapes.SHAPES[rec["shape"]]
        memory_s = _memory_model_bytes(rec, cfg, sh) / HBM_BW
    except Exception:
        memory_s = memory_hlo_s
    collective_s = wire_dev / LINK_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])

    model_flops = rec.get("model_flops_global", 0.0)
    useful_ratio = model_flops / (flops_dev * n) if flops_dev else 0.0

    ideal_compute = model_flops / (n * PEAK_FLOPS)
    src = deploy or rec
    min_bytes_dev = src.get("arg_bytes_per_device", 0.0)
    ideal = max(ideal_compute, min_bytes_dev / HBM_BW)
    fraction = ideal / dominant[1] if dominant[1] > 0 else 0.0

    ma = (deploy or {}).get("memory_analysis") or rec.get("memory_analysis")
    temp_gb = (ma.get("temp_size_in_bytes", 0) / 1e9
               if isinstance(ma, dict) else float("nan"))
    arg_gb = src.get("arg_bytes_per_device", 0) / 1e9
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "collective_s": collective_s,
        "collective_operand_s": operand_dev / LINK_BW,
        "dominant": dominant[0],
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": fraction,
        "ideal_s": ideal,
        "temp_gb_per_device": temp_gb,
        "arg_gb_per_device": arg_gb,
        "fits_hbm16": (temp_gb + arg_gb) <= 16.0,
    }


def load_records(dirname: str) -> dict:
    out = {}
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


def load_all(probe_dir: str = "results/probe",
             deploy_dir: str = "results/dryrun") -> list[dict]:
    probes = load_records(probe_dir)
    deploys = load_records(deploy_dir)
    rows = []
    for key, rec in sorted(probes.items()):
        row = analyze_record(rec, deploy=deploys.get(key))
        if row:
            rows.append(row)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s (model) | memory s (HLO) "
           "| collective s | dominant | useful FLOPs | roofline frac "
           "| temp+arg GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['memory_hlo_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['temp_gb_per_device'] + r['arg_gb_per_device']:.1f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe-dir", default="results/probe")
    ap.add_argument("--deploy-dir", default="results/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_all(args.probe_dir, args.deploy_dir)
    if args.csv:
        for r in rows:
            print(f"roofline_{r['arch']}_{r['shape']}"
                  f"{('_' + r['tag']) if r['tag'] and r['tag'] != 'probe' else ''},"
                  f"{r['compute_s']*1e6:.1f},"
                  f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
                  f"mem_us={r['memory_s']*1e6:.1f};"
                  f"coll_us={r['collective_s']*1e6:.1f}")
    else:
        print(markdown_table(rows))


if __name__ == "__main__":
    main()
