"""The paper's four evaluation workflows (§6.2), rebuilt on Helix-JAX.

Each factory builds a Workflow from a knob dataclass; ``mutate`` applies a
random edit of a given kind (DPR / LI / PPR), and ``ITERATION_FREQS`` encode
the per-domain edit-type frequencies from the paper's applied-ML survey
([78], used in §6.3): census is PPR-heavy (social-science result analysis),
NLP is DPR-only, genomics is L/I-heavy, MNIST is mixed.

All compute is real (JAX/numpy): CSV parsing, learned discretization,
logistic-regression training, skip-gram embeddings, k-means, a transformer
encoder as the expensive "NLP parse", random-FFT features (nondeterministic,
as in KeystoneML's MNIST pipeline).
"""
from __future__ import annotations

import dataclasses
import io
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import Workflow
from repro.data import synth, tabular
from repro.models.config import ArchConfig
from repro.train import steps as train_steps


# ---------------------------------------------------------------------------
# small JAX learners shared by the workflows
# ---------------------------------------------------------------------------
def train_logreg(X: np.ndarray, y: np.ndarray, reg: float, iters: int = 300,
                 lr: float = 0.5) -> np.ndarray:
    Xj, yj = jnp.asarray(X), jnp.asarray(y, jnp.float32)

    def loss(w):
        logits = Xj @ w[:-1] + w[-1]
        ce = jnp.mean(jnp.logaddexp(0.0, logits) - yj * logits)
        return ce + reg * jnp.sum(w[:-1] ** 2)

    w = jnp.zeros(X.shape[1] + 1)
    g = jax.jit(jax.grad(loss))
    for _ in range(iters):
        w = w - lr * g(w)
    return np.asarray(w)


def logreg_predict(w: np.ndarray, X: np.ndarray) -> np.ndarray:
    return (X @ w[:-1] + w[-1] > 0).astype(np.int32)


def train_embeddings(docs: np.ndarray, vocab: int, dim: int, epochs: int,
                     seed: int = 0) -> np.ndarray:
    """Skip-gram-ish embeddings via jitted SGD over co-occurrence pairs."""
    rng = np.random.default_rng(seed)
    centers = docs[:, :-1].reshape(-1)
    contexts = docs[:, 1:].reshape(-1)
    neg = rng.integers(0, vocab, len(centers))
    E = jnp.asarray(rng.normal(0, 0.1, (vocab, dim)), jnp.float32)

    @jax.jit
    def epoch(E):
        def loss(E):
            c = E[centers]
            pos = jnp.sum(c * E[contexts], -1)
            ngs = jnp.sum(c * E[neg], -1)
            return jnp.mean(jnp.logaddexp(0, -pos) + jnp.logaddexp(0, ngs))
        return E - 0.5 * jax.grad(loss)(E)

    for _ in range(epochs):
        E = epoch(E)
    return np.asarray(E)


def kmeans(X: np.ndarray, k: int, iters: int = 25, seed: int = 0
           ) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    C = jnp.asarray(X[rng.choice(len(X), k, replace=False)])
    Xj = jnp.asarray(X)

    @jax.jit
    def step(C):
        d = jnp.sum((Xj[:, None] - C[None]) ** 2, -1)
        assign = jnp.argmin(d, 1)
        onehot = jax.nn.one_hot(assign, k)
        counts = onehot.sum(0)[:, None] + 1e-9
        return (onehot.T @ Xj) / counts, assign

    for _ in range(iters):
        C, assign = step(C)
    return np.asarray(C), np.asarray(assign)


def encoder_parse(docs: np.ndarray, vocab: int, seed: int = 0,
                  dim: int = 128, layers: int = 4) -> np.ndarray:
    """The NLP workflow's expensive 'parse': a transformer encoder over every
    document (stands in for CoreNLP in the paper's IE workflow)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + 4 * layers)
    E = jax.random.normal(ks[0], (vocab, dim)) * 0.05
    Ws = [tuple(jax.random.normal(ks[2 + 4 * i + j], (dim, dim)) * dim ** -0.5
                for j in range(4)) for i in range(layers)]

    @jax.jit
    def run(tok):
        h = E[tok]
        for wq, wk, wv, wo in Ws:
            q, k_, v = h @ wq, h @ wk, h @ wv
            a = jax.nn.softmax(q @ k_.swapaxes(-1, -2) / dim ** 0.5, -1)
            h = h + (a @ v) @ wo
            h = h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)
        return h

    out = []
    for i in range(0, len(docs), 256):
        out.append(np.asarray(run(jnp.asarray(docs[i:i + 256]))))
    return np.concatenate(out)


# ---------------------------------------------------------------------------
# 1. census (the paper's running example, Fig. 3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CensusKnobs:
    n_rows: int = 120_000
    n_buckets: int = 10
    use_interaction: bool = True
    use_hours: bool = True
    reg: float = 0.1
    train_iters: int = 300        # halving resource (SGD steps)
    eval_threshold: float = 0.5   # PPR knob (report formatting)
    eval_metric: str = "accuracy"


def build_census(k: CensusKnobs) -> Workflow:
    wf = Workflow("census")

    def load_csv():
        rows = synth.census_rows(7, k.n_rows)
        buf = io.StringIO()
        cols = sorted(rows)
        for i in range(k.n_rows):
            buf.write(",".join(str(rows[c][i]) for c in cols) + "\n")
        return cols, buf.getvalue()

    raw = wf.source("data", load_csv, config=("census-v1", k.n_rows))

    def parse(raw):
        cols, text = raw
        mat = np.loadtxt(io.StringIO(text), delimiter=",", dtype=np.int64)
        return {c: mat[:, i] for i, c in enumerate(cols)}

    rows = wf.scanner("rows", parse, [raw], config="csv")

    age = wf.extractor("ageExt", lambda r: tabular.standardize(r["age"]),
                       [rows], config="age")
    edu = wf.extractor("eduExt", lambda r: tabular.one_hot(r["education"], 16),
                       [rows], config="edu")
    occ = wf.extractor("occExt", lambda r: tabular.one_hot(r["occupation"], 15),
                       [rows], config="occ")
    cg = wf.extractor("cgExt", lambda r: tabular.standardize(
        np.log1p(r["capital_gain"])), [rows], config="cg")
    sex = wf.extractor("sexExt", lambda r: tabular.one_hot(r["sex"], 2),
                       [rows], config="sex")
    # raceExt exists but is excluded from the synthesizer → pruned (§5.4)
    wf.extractor("raceExt", lambda r: tabular.one_hot(r["race"], 5),
                 [rows], config="race")
    ageb = wf.extractor(
        "ageBucket", lambda r: tabular.one_hot(
            tabular.bucketize(r["age"], k.n_buckets), k.n_buckets),
        [rows], config=("bucket", k.n_buckets))
    feats = [age, edu, occ, cg, sex, ageb]
    if k.use_hours:
        feats.append(wf.extractor(
            "hoursExt", lambda r: tabular.standardize(r["hours"]),
            [rows], config="hours"))
    if k.use_interaction:
        feats.append(wf.extractor(
            "eduXocc", lambda r: tabular.interact(
                tabular.one_hot(r["education"], 16),
                tabular.one_hot(r["occupation"], 15)),
            [rows], config="interact"))

    def make_examples(rows_v, *blocks):
        X, prov = tabular.assemble(
            {f"b{i}": b for i, b in enumerate(blocks)})
        y = rows_v["target"].astype(np.int32)
        n_train = int(0.8 * len(y))
        return dict(X=X, y=y, n_train=n_train, provenance=prov)

    income = wf.synthesizer("income", make_examples, [rows] + feats,
                            config=("examples", len(feats)))

    model = wf.learner(
        "incPred", lambda ex: train_logreg(
            ex["X"][:ex["n_train"]], ex["y"][:ex["n_train"]], k.reg,
            iters=k.train_iters),
        [income], config=("LR", k.reg, k.train_iters))

    preds = wf.learner(
        "predictions", lambda ex, w: logreg_predict(w, ex["X"]),
        [income, model], config="predict")

    def check(ex, p):
        test = slice(ex["n_train"], None)
        yt, pt = ex["y"][test], p[test]
        if k.eval_metric == "accuracy":
            val = float((yt == pt).mean())
        else:  # f1
            tp = float(((yt == 1) & (pt == 1)).sum())
            prec = tp / max(float((pt == 1).sum()), 1)
            rec = tp / max(float((yt == 1).sum()), 1)
            val = 2 * prec * rec / max(prec + rec, 1e-9)
        return {"metric": k.eval_metric, "value": val,
                "threshold_pass": val > k.eval_threshold}

    checked = wf.reducer("checkResults", check, [income, preds],
                         config=("eval", k.eval_metric, k.eval_threshold))
    wf.output(checked)
    return wf


# ---------------------------------------------------------------------------
# 1b. census, daily-retrain variant (chunk-partitioned source — chunks.py)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IncrementalCensusKnobs:
    """The append-mostly census scenario: ``n_chunks`` daily batches of
    ``rows_per_chunk`` rows; a retrain after a day's append sees one new
    chunk. Featurization dominates the cost by design (wide one-hot
    interactions, per-row → ``incremental="map"``), which is exactly the
    regime where chunk splicing pays: the learner retrains on every
    append regardless, but the feature matrix is 90 %-cached."""

    n_chunks: int = 10
    rows_per_chunk: int = 8_000
    seed: int = 7
    feat_dim: int = 512          # random-feature width (featurize layers)
    feat_layers: int = 8         # cos-layer depth: the dominant, map-safe cost
    reg: float = 0.1
    train_iters: int = 15


def train_logreg_np(X: np.ndarray, y: np.ndarray, reg: float, iters: int,
                    lr: float = 0.5) -> np.ndarray:
    """Binary logistic regression in plain numpy (deterministic, no jit
    compile constant — the daily-retrain bench compares delta vs. cold
    wall-clock, and an XLA compile identical in both runs would wash out
    the splice signal at CI-smoke scale)."""
    X = np.ascontiguousarray(X, np.float32)
    yf = np.asarray(y, np.float32)
    w = np.zeros(X.shape[1], np.float32)
    b = np.float32(0.0)
    n = np.float32(len(y))
    for _ in range(iters):
        z = X @ w + b
        p = np.float32(1.0) / (np.float32(1.0) + np.exp(-z))
        err = p - yf
        w -= np.float32(lr) * (X.T @ err / n
                               + np.float32(2 * reg) * w)
        b -= np.float32(lr) * err.mean()
    return np.concatenate([w, [b]]).astype(np.float64)


def build_census_incremental(k: IncrementalCensusKnobs) -> Workflow:
    descs = tabular.census_chunk_descriptors(k.seed, k.n_chunks,
                                             k.rows_per_chunk)
    wf = Workflow("census_inc")
    rows = wf.source("rows", lambda: tabular.load_census_chunks(descs),
                     chunks=descs)

    # Row-local featurization (map-safe: one_hot / fixed_bucketize and a
    # fixed-weight random-feature expansion depend only on their own row
    # — see tabular.py). The two cos-layers are the deliberately
    # dominant cost: this is the work chunk splicing saves.
    def featurize(r):
        base = np.concatenate([
            tabular.one_hot(r["education"], 16),
            tabular.one_hot(r["occupation"], 15),
            tabular.one_hot(r["sex"], 2),
            tabular.one_hot(tabular.fixed_bucketize(
                r["age"], range(20, 90, 7)), 11),
            tabular.one_hot(tabular.fixed_bucketize(
                r["hours"], range(10, 90, 8)), 11),
        ], axis=1)
        rng = np.random.default_rng(12345)   # fixed weights: deterministic
        w1 = rng.normal(0, 0.3, (base.shape[1], k.feat_dim)
                        ).astype(np.float32)
        b1 = rng.uniform(0, 2 * np.pi, k.feat_dim).astype(np.float32)
        h = np.cos(base @ w1 + b1)
        for _ in range(max(k.feat_layers - 1, 0)):
            w2 = rng.normal(0, 0.1, (k.feat_dim, k.feat_dim)
                            ).astype(np.float32)
            b2 = rng.uniform(0, 2 * np.pi, k.feat_dim).astype(np.float32)
            h = np.cos(h @ w2 + b2)
        return h

    feats = wf.extractor("rowFeats", featurize, [rows],
                         config=("rowfeat-v1", k.feat_dim, k.feat_layers),
                         incremental="map")
    labels = wf.extractor("labels",
                          lambda r: r["target"].astype(np.int32), [rows],
                          config="labels", incremental="map")
    # Column sums — genuinely associative under fn re-application:
    # sum(concat(chunks)) == sum(stack(per-chunk sums)).
    fsum = wf.reducer("featSums", lambda X: np.sum(X, axis=0,
                                                   dtype=np.float64),
                      [feats], config="sums", incremental="assoc_reduce")

    def train(X, y, sums):
        scale = (1.0 / np.sqrt(1.0 + np.abs(sums) / max(len(y), 1))
                 ).astype(np.float32)
        return train_logreg_np(X * scale, y, k.reg, iters=k.train_iters)

    model = wf.learner("incModel", train, [feats, labels, fsum],
                       config=("LRnp", k.reg, k.train_iters))

    def evaluate(X, y, sums, w):
        scale = (1.0 / np.sqrt(1.0 + np.abs(sums) / max(len(y), 1))
                 ).astype(np.float32)
        p = ((X * scale) @ w[:-1] + w[-1] > 0).astype(np.int32)
        return {"accuracy": float((p == y).mean()), "n_rows": len(y)}

    out = wf.reducer("dailyEval", evaluate, [feats, labels, fsum, model],
                     config="eval")
    wf.output(out)
    return wf


def mutate_census(k: CensusKnobs, kind: str, rng: np.random.Generator
                  ) -> CensusKnobs:
    if kind == "DPR":
        choice = rng.integers(0, 3)
        if choice == 0:
            return dataclasses.replace(k, n_buckets=int(rng.integers(4, 16)))
        if choice == 1:
            return dataclasses.replace(k, use_interaction=not k.use_interaction)
        return dataclasses.replace(k, use_hours=not k.use_hours)
    if kind == "LI":
        return dataclasses.replace(k, reg=float(rng.choice(
            [0.01, 0.03, 0.1, 0.3, 1.0])))
    return dataclasses.replace(
        k, eval_threshold=float(rng.uniform(0.4, 0.9)),
        eval_metric=str(rng.choice(["accuracy", "f1"])))


# ---------------------------------------------------------------------------
# 2. genomics (Example 1: embed entities, cluster)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GenomicsKnobs:
    n_docs: int = 3000
    vocab: int = 4000
    emb_dim: int = 64
    emb_epochs: int = 12
    n_clusters: int = 16
    kb_size: int = 400
    report_top: int = 5


def build_genomics(k: GenomicsKnobs) -> Workflow:
    wf = Workflow("genomics")
    docs = wf.source("articles", lambda: synth.documents(
        11, k.n_docs, 160, k.vocab), config=("docs", k.n_docs, k.vocab))
    kb = wf.source("geneKB", lambda: np.arange(0, k.vocab, k.vocab // k.kb_size,
                                               dtype=np.int32),
                   config=("kb", k.kb_size))
    ents = wf.synthesizer(
        "entities", lambda d, g: np.intersect1d(np.unique(d), g),
        [docs, kb], config="join")
    emb = wf.learner(
        "word2vec", lambda d: train_embeddings(
            d, k.vocab, k.emb_dim, k.emb_epochs),
        [docs], config=("w2v", k.emb_dim, k.emb_epochs))
    gene_emb = wf.extractor("geneVectors", lambda E, e: E[e],
                            [emb, ents], config="gather")
    clusters = wf.learner(
        "kmeans", lambda X: kmeans(X, k.n_clusters),
        [gene_emb], config=("km", k.n_clusters))

    def report(X, cl):
        C, assign = cl
        d = np.linalg.norm(X - C[assign], axis=1)
        sizes = np.bincount(assign, minlength=k.n_clusters)
        top = np.argsort(sizes)[::-1][:k.report_top]
        return {"inertia": float((d ** 2).sum()),
                "top_cluster_sizes": sizes[top].tolist()}

    out = wf.reducer("clusterReport", report, [gene_emb, clusters],
                     config=("report", k.report_top))
    wf.output(out)
    return wf


def mutate_genomics(k: GenomicsKnobs, kind: str, rng) -> GenomicsKnobs:
    if kind == "DPR":
        if rng.random() < 0.5:
            return dataclasses.replace(k, n_docs=int(rng.choice(
                [2000, 3000, 4000])))
        return dataclasses.replace(k, kb_size=int(rng.choice([200, 400, 800])))
    if kind == "LI":
        if rng.random() < 0.5:
            return dataclasses.replace(k, emb_dim=int(rng.choice([32, 64, 96])))
        return dataclasses.replace(k, n_clusters=int(rng.choice([8, 16, 32])))
    return dataclasses.replace(k, report_top=int(rng.integers(3, 10)))


# ---------------------------------------------------------------------------
# 3. NLP / IE (spouse extraction analogue; DPR-only iterations)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NLPKnobs:
    n_docs: int = 1200
    vocab: int = 2000
    pair_window: int = 8
    feat_dim: int = 64
    reg: float = 0.1


def build_nlp(k: NLPKnobs) -> Workflow:
    wf = Workflow("nlp_ie")
    docs = wf.source("news", lambda: synth.documents(
        23, k.n_docs, 240, k.vocab), config=("docs", k.n_docs))
    kb = wf.source("knownPairs", lambda: np.stack(
        [np.arange(0, 200, 2), np.arange(1, 200, 2)], 1).astype(np.int32),
        config="pairs")
    # The expensive, reusable step (paper §6.5.2 "NLP"): parse everything.
    parsed = wf.scanner("corenlp", lambda d: encoder_parse(d, k.vocab),
                        [docs], config="parse-v1")

    def candidates(d, emb, pairs):
        pset = {tuple(p) for p in pairs.tolist()}
        feats, labels = [], []
        for i in range(len(d)):
            toks = d[i]
            for j in range(0, len(toks) - k.pair_window, k.pair_window):
                a, b = int(toks[j]), int(toks[j + k.pair_window - 1])
                v = np.concatenate([emb[i, j], emb[i, j + k.pair_window - 1]])
                feats.append(v[:k.feat_dim])
                labels.append(1 if (a, b) in pset or (b, a) in pset else 0)
        return np.stack(feats).astype(np.float32), np.asarray(labels, np.int32)

    cand = wf.synthesizer("candidates", candidates, [docs, parsed, kb],
                          config=("cand", k.pair_window, k.feat_dim))
    model = wf.learner(
        "spouseLR", lambda c: train_logreg(c[0], c[1], k.reg, iters=200),
        [cand], config=("LR", k.reg))

    def f1(c, w):
        X, y = c
        p = logreg_predict(w, X)
        tp = float(((y == 1) & (p == 1)).sum())
        prec = tp / max(float((p == 1).sum()), 1)
        rec = tp / max(float((y == 1).sum()), 1)
        return {"f1": 2 * prec * rec / max(prec + rec, 1e-9)}

    out = wf.reducer("scoreF1", f1, [cand, model], config="f1")
    wf.output(out)
    return wf


def mutate_nlp(k: NLPKnobs, kind: str, rng) -> NLPKnobs:
    # paper: the NLP workflow only has DPR iterations
    if rng.random() < 0.5:
        return dataclasses.replace(k, pair_window=int(rng.choice([4, 6, 8, 12])))
    return dataclasses.replace(k, feat_dim=int(rng.choice([32, 64, 128])))


# ---------------------------------------------------------------------------
# 4. MNIST (nondeterministic featurization → little reuse)
# ---------------------------------------------------------------------------
def train_softmax_np(Z: np.ndarray, y: np.ndarray, reg: float, epochs: int,
                     lr: float = 0.5) -> np.ndarray:
    """Softmax regression in plain numpy (BLAS releases the GIL, so tower
    branches using it parallelize across the pipelined executor's
    workers — the jitted jax path serializes on XLA's CPU runtime)."""
    W = np.zeros((Z.shape[1], 10), np.float32)
    n = len(y)
    idx = np.arange(n)
    for _ in range(epochs):
        logits = Z @ W
        logits -= logits.max(1, keepdims=True)
        p = np.exp(logits)
        p /= p.sum(1, keepdims=True)
        p[idx, y] -= 1.0
        W -= lr * (Z.T @ p / n + 2 * reg * W)
    return W


@dataclasses.dataclass(frozen=True)
class MNISTKnobs:
    n_images: int = 12_000
    n_features: int = 512
    # >1 splits featurization into independent random-FFT towers of
    # n_features/n_towers features, each training its own softmax head
    # (KeystoneML-style block solve, ensembled by logit summation) — the
    # DAG branch parallelism the pipelined executor exploits.
    n_towers: int = 1
    reg: float = 1e-3
    epochs: int = 60
    eval_k: int = 1


def build_mnist(k: MNISTKnobs) -> Workflow:
    wf = Workflow("mnist")
    imgs = wf.source("mnist", lambda: synth.images(5, k.n_images),
                     config=("imgs", k.n_images))

    def random_fft_block(n_feat):
        def block(data):
            X, y = data
            # Nondeterministic (fresh projection every run) — mirrors
            # KeystoneML's RandomFFT featurization; cannot be reused.
            rng = np.random.default_rng()
            W = rng.normal(0, 1.0, (X.shape[1] * X.shape[2], n_feat)
                           ).astype(np.float32)
            b = rng.uniform(0, 2 * np.pi, n_feat).astype(np.float32)
            Z = np.cos(X.reshape(len(X), -1).astype(np.float32) @ W + b)
            return Z, y
        return block

    if k.n_towers > 1:
        per_tower = k.n_features // k.n_towers
        logit_nodes = []
        for t in range(k.n_towers):
            z = wf.extractor(f"fftTower{t}", random_fft_block(per_tower),
                             [imgs], config=("fft", per_tower, t),
                             deterministic=False)
            head = wf.learner(
                f"towerHead{t}",
                lambda zy: train_softmax_np(zy[0], zy[1], k.reg, k.epochs),
                [z], config=("smnp", k.reg, k.epochs, t))
            logit_nodes.append(wf.learner(
                f"towerLogits{t}", lambda zy, w: zy[0] @ w,
                [z, head], config=("logits", t)))

        def ensemble_acc(data, *logit_blocks):
            _, y = data
            pred = np.argmax(np.sum(logit_blocks, axis=0), 1)
            return {"top1": float((pred == y).mean())}

        out = wf.reducer("evalAcc", ensemble_acc, [imgs] + logit_nodes,
                         config=("acc", k.eval_k, k.n_towers))
        wf.output(out)
        return wf

    feats = wf.extractor("randomFFT", random_fft_block(k.n_features),
                         [imgs], config=("fft", k.n_features),
                         deterministic=False)

    def train_softmax(data):
        Z, y = data
        Zj, yj = jnp.asarray(Z), jnp.asarray(y)
        W = jnp.zeros((Z.shape[1], 10))

        @jax.jit
        def step(W):
            logits = Zj @ W
            ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(yj)), yj])
            return W - 0.5 * jax.grad(
                lambda W: ce + k.reg * jnp.sum(W * W))(W)

        # re-derive grad correctly (closure above must recompute ce)
        def loss(W):
            logits = Zj @ W
            ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(yj)), yj])
            return ce + k.reg * jnp.sum(W * W)
        g = jax.jit(jax.grad(loss))
        for _ in range(k.epochs):
            W = W - 0.5 * g(W)
        return np.asarray(W)

    model = wf.learner("softmax", train_softmax, [feats],
                       config=("sm", k.reg, k.epochs))

    def acc(data, W):
        Z, y = data
        pred = np.argmax(Z @ W, 1)
        return {"top1": float((pred == y).mean())}

    out = wf.reducer("evalAcc", acc, [feats, model],
                     config=("acc", k.eval_k))
    wf.output(out)
    return wf


def mutate_mnist(k: MNISTKnobs, kind: str, rng) -> MNISTKnobs:
    if kind == "DPR":
        return dataclasses.replace(k, n_features=int(rng.choice(
            [256, 512, 768])))
    if kind == "LI":
        return dataclasses.replace(k, reg=float(rng.choice(
            [1e-4, 1e-3, 1e-2])), epochs=int(rng.choice([40, 60, 80])))
    return dataclasses.replace(k, eval_k=int(rng.integers(1, 5)))


# ---------------------------------------------------------------------------
# 5. LM training (small transformer; large pytree materializations)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LMKnobs:
    """A small-config LM training loop on the model zoo's dense family.

    Unlike the four survey workflows, the expensive reusable artifacts
    here are *pytrees of jax arrays* (a TrainState of params + AdamW
    moments), which is what the store's memory tier exists to serve
    zero-copy: a warm rerun should replay the trained state from host
    RAM without touching a single ``.npy``."""

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    vocab: int = 512
    seq_len: int = 64
    batch: int = 8
    steps: int = 4                # train batches (halving resource, LI)
    peak_lr: float = 1e-3
    seed: int = 0
    report_percentiles: bool = False   # PPR knob (loss-report formatting)


def _lm_arch(k: LMKnobs) -> ArchConfig:
    # attn_impl="chunked" — pure-jnp attention; the Pallas flash kernel
    # needs a TPU and this workflow must run on the CI's CPU.
    return ArchConfig(
        name="bench-lm", family="dense", num_layers=k.n_layers,
        d_model=k.d_model, num_heads=k.n_heads, num_kv_heads=k.n_heads,
        d_ff=k.d_ff, vocab_size=k.vocab, attn_impl="chunked")


def build_lm(k: LMKnobs) -> Workflow:
    cfg = _lm_arch(k)
    wf = Workflow("lm")

    def make_tokens():
        rng = np.random.default_rng(k.seed + 101)
        # steps train batches + 1 held-out eval batch
        return rng.integers(0, k.vocab, (k.steps + 1, k.batch, k.seq_len),
                            dtype=np.int32)

    tokens = wf.source("tokens", make_tokens,
                       config=("tok", k.vocab, k.seq_len, k.batch, k.steps,
                               k.seed))
    state0 = wf.source(
        "initState",
        lambda: train_steps.init_train_state(cfg, jax.random.PRNGKey(k.seed)),
        config=("init", k.n_layers, k.d_model, k.n_heads, k.d_ff, k.vocab,
                k.seed))

    def train(tok, state):
        step = jax.jit(lambda s, b: train_steps.train_step(
            cfg, s, b, peak_lr=k.peak_lr, warmup_steps=2,
            total_steps=max(k.steps, 3), clip_norm=1.0))
        losses = []
        for i in range(k.steps):
            state, metrics = step(state, {"tokens": jnp.asarray(tok[i])})
            losses.append(float(metrics["loss"]))
        return {"state": state, "losses": np.asarray(losses, np.float64)}

    trained = wf.learner(
        "train", train, [tokens, state0],
        config=("train", k.n_layers, k.d_model, k.n_heads, k.d_ff, k.vocab,
                k.seq_len, k.batch, k.steps, k.peak_lr))

    def eval_loss(tok, tr):
        loss, _ = train_steps.loss_fn(
            cfg, tr["state"].params, {"tokens": jnp.asarray(tok[-1])})
        out = {"eval_loss": float(loss),
               "train_losses": tr["losses"].tolist()}
        if k.report_percentiles:
            qs = np.percentile(tr["losses"], [0, 50, 100])
            out["loss_percentiles"] = {"p0": float(qs[0]),
                                       "p50": float(qs[1]),
                                       "p100": float(qs[2])}
        return out

    out = wf.reducer("evalLoss", eval_loss, [tokens, trained],
                     config=("eval", k.report_percentiles))
    wf.output(out)
    return wf


def mutate_lm(k: LMKnobs, kind: str, rng) -> LMKnobs:
    if kind == "DPR":
        if rng.random() < 0.5:
            return dataclasses.replace(k, seq_len=int(rng.choice(
                [48, 64, 96])))
        return dataclasses.replace(k, batch=int(rng.choice([4, 8])))
    if kind == "LI":
        if rng.random() < 0.5:
            return dataclasses.replace(k, peak_lr=float(rng.choice(
                [3e-4, 1e-3, 3e-3])))
        return dataclasses.replace(k, steps=int(rng.choice([3, 4, 6])))
    return dataclasses.replace(
        k, report_percentiles=not k.report_percentiles)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkflowDef:
    name: str
    knobs0: object
    build: Callable
    mutate: Callable
    freqs: dict     # DPR/LI/PPR iteration-type frequencies (survey [78])


WORKFLOWS = {
    "census": WorkflowDef("census", CensusKnobs(), build_census,
                          mutate_census,
                          {"DPR": 0.3, "LI": 0.2, "PPR": 0.5}),
    "genomics": WorkflowDef("genomics", GenomicsKnobs(), build_genomics,
                            mutate_genomics,
                            {"DPR": 0.2, "LI": 0.5, "PPR": 0.3}),
    "nlp": WorkflowDef("nlp", NLPKnobs(), build_nlp, mutate_nlp,
                       {"DPR": 1.0, "LI": 0.0, "PPR": 0.0}),
    "mnist": WorkflowDef("mnist", MNISTKnobs(), build_mnist, mutate_mnist,
                         {"DPR": 0.3, "LI": 0.4, "PPR": 0.3}),
    "lm": WorkflowDef("lm", LMKnobs(), build_lm, mutate_lm,
                      {"DPR": 0.3, "LI": 0.5, "PPR": 0.2}),
}


def iteration_schedule(wd: WorkflowDef, n_iters: int, seed: int
                       ) -> list[object]:
    """knobs for iterations 0..n-1 (0 = initial)."""
    rng = np.random.default_rng(seed)
    kinds = list(wd.freqs)
    probs = np.asarray([wd.freqs[x] for x in kinds])
    probs = probs / probs.sum()
    knobs = [wd.knobs0]
    cur = wd.knobs0
    for _ in range(n_iters - 1):
        kind = str(rng.choice(kinds, p=probs))
        nxt = wd.mutate(cur, kind, rng)
        tries = 0
        while nxt == cur and tries < 5:   # ensure an actual edit
            nxt = wd.mutate(cur, kind, rng)
            tries += 1
        knobs.append(nxt)
        cur = nxt
    return knobs
